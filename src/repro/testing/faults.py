"""Fault-injection harness for the multi-level resilience hierarchy.

The kill-a-host test matrix (tests/test_resilience.py) needs repeatable,
precisely-placed failures: a host dying *between* two protocol phases, a
shard file torn mid-write, a replica whose CRC lies, a writer that
stalls, a partner that dies during an L2 fetch.  This module packages
those as reusable injectors so every test states its failure scenario in
one line instead of hand-rolled monkeypatching:

- ``FaultInjector`` + the coordinator's named seams (``pack_done``,
  ``after_replicate``, ``after_land_write``, ``before_commit_barrier``,
  ``after_commit``) place a failure between any two save phases;
- ``FaultyCollective`` wraps any ``Collective`` to kill a host exactly at
  (before/after) a named barrier;
- file-level helpers (``tear_file``, ``corrupt_crc``) damage durable
  state the way real torn writes and bit rot do;
- ``stalled_writer`` / ``partner_fetch_failure`` context managers patch
  the store/replica I/O paths for slow-writer and dead-partner
  scenarios;
- ``injector_from_env`` builds an injector from ``REPRO_FAULT`` so the
  *subprocess* multi-host harness can arm faults in its children
  (``hard=True`` kills via ``os._exit`` — a real process death, not an
  exception the save path could catch).

Thread-simulated hosts die by raising ``HostKilled`` — a
``BaseException`` so no production ``except Exception`` handler can
swallow the death, mirroring how a real process loss is invisible to the
dying host's own code.
"""

from __future__ import annotations

import contextlib
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.distributed.collective import Collective

FAULT_ENV = "REPRO_FAULT"

#: the coordinator's save-path seams, in protocol order
SAVE_POINTS = ("pack_done", "after_replicate", "after_land_write",
               "before_commit_barrier", "after_commit")


class HostKilled(BaseException):
    """Simulated abrupt host death (thread-simulated harness)."""

    def __init__(self, where: str):
        self.where = where
        super().__init__(f"host killed at {where}")


def _default_kill(hard: bool, where: str) -> None:
    if hard:
        os._exit(17)            # noqa: SLF001 - simulate a real host death
    raise HostKilled(where)


class _Rule:
    def __init__(self, point: str, action: Optional[Callable] = None,
                 match: Optional[str] = None, times: int = 1,
                 hard: bool = False):
        self.point = point
        self.action = action
        self.match = match
        self.times = int(times)
        self.hard = hard

    def applies(self, point: str, ctx: Dict[str, Any]) -> bool:
        if self.times <= 0 or self.point != point:
            return False
        if self.match is not None:
            hay = str(ctx.get("name", "")) or " ".join(
                f"{k}={v}" for k, v in sorted(ctx.items()))
            if self.match not in hay:
                return False
        return True


class FaultInjector:
    """Named-seam fault registry.

    Instrumented code calls ``fire(point, **ctx)`` at its seams; each
    armed rule matching ``point`` (and, optionally, a substring of the
    context's ``name``) fires up to ``times`` times.  A rule without an
    explicit action kills the host (``HostKilled``, or ``os._exit`` when
    ``hard`` — for subprocess harnesses where a catchable exception would
    understate the failure).
    """

    def __init__(self):
        self.rules: List[_Rule] = []
        self.fired: List[str] = []

    def at(self, point: str, action: Optional[Callable] = None, *,
           match: Optional[str] = None, times: int = 1,
           hard: bool = False) -> "FaultInjector":
        self.rules.append(_Rule(point, action, match, times, hard))
        return self

    def kill_at(self, point: str, *, match: Optional[str] = None,
                hard: bool = False) -> "FaultInjector":
        return self.at(point, match=match, hard=hard)

    def fire(self, point: str, **ctx) -> None:
        for r in self.rules:
            if not r.applies(point, ctx):
                continue
            r.times -= 1
            self.fired.append(point)
            if r.action is None:
                _default_kill(r.hard, point)
            else:
                r.action(ctx)


class FaultyCollective(Collective):
    """A ``Collective`` whose host dies at a chosen barrier.

    Wraps any backend; ``kill_before(substr)`` / ``kill_after(substr)``
    arm a death at the first barrier whose name contains ``substr`` —
    before touching the rendezvous (the host never arrives: survivors
    get a ``BarrierTimeout`` naming it) or after passing it (the host
    saw the rendezvous complete, then died).
    """

    def __init__(self, inner: Collective, hard: bool = False):
        super().__init__(inner.ctx)
        self.inner = inner
        self.hard = hard
        self._before: List[List] = []   # [substr, times]
        self._after: List[List] = []
        self.barriers_seen: List[str] = []

    def kill_before(self, substr: str, times: int = 1) -> "FaultyCollective":
        self._before.append([substr, int(times)])
        return self

    def kill_after(self, substr: str, times: int = 1) -> "FaultyCollective":
        self._after.append([substr, int(times)])
        return self

    def _check(self, rules: List[List], name: str) -> None:
        for r in rules:
            if r[1] > 0 and r[0] in name:
                r[1] -= 1
                _default_kill(self.hard, f"barrier {name!r}")

    def barrier(self, name: str, timeout: Optional[float] = None,
                participants: Optional[Sequence[int]] = None,
                heartbeat: Optional[Callable] = None) -> None:
        self.barriers_seen.append(name)
        self._check(self._before, name)
        self.inner.barrier(name, timeout=timeout, participants=participants,
                           heartbeat=heartbeat)
        self._check(self._after, name)

    def cleanup(self, before_seq: int) -> None:
        self.inner.cleanup(before_seq)

    def close(self) -> None:
        self.inner.close()


# --------------------------------------------------------------------------
# Durable-state damage: torn writes and bit rot
# --------------------------------------------------------------------------

def tear_file(path: str, keep_bytes: Optional[int] = None,
              frac: float = 0.5) -> int:
    """Truncate ``path`` as a torn write would: keep ``keep_bytes`` (or
    ``frac`` of the file).  Returns the new size."""
    size = os.path.getsize(path)
    keep = int(size * frac) if keep_bytes is None else int(keep_bytes)
    keep = max(0, min(keep, size))
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep


def corrupt_crc(path: str, offset: Optional[int] = None) -> None:
    """Flip one payload byte so every CRC covering it fails."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot corrupt empty file {path}")
    off = (size // 2) if offset is None else int(offset)
    off = min(max(off, 0), size - 1)
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))


def shard_files(step_dir: str) -> List[str]:
    """Every payload file of a (pending or committed) checkpoint dir."""
    return sorted(os.path.join(step_dir, f) for f in os.listdir(step_dir)
                  if f.endswith(".bin"))


def session_shard_files(root: str, step: int,
                        sid: Optional[str] = None) -> List[str]:
    """Shard files holding a *session snapshot*'s payload bytes.

    Session checkpoints name their leaves ``sessions/<sid>/…``; this
    resolves which committed *data* shard files carry a given session's
    segments (all sessions when ``sid`` is None) so tests can aim
    ``tear_file`` / ``corrupt_crc`` at exactly one session's durable
    bytes.  Coordinated segments name their per-host file directly;
    plain single-host layouts record a numbered shard index instead
    (``shard_<k>.bin``).  Parity files are never returned — damaging
    those would test nothing.
    """
    from repro.checkpoint.coordinator import GlobalManifest
    gm = GlobalManifest.load(root, step)
    prefix = "sessions/" + (f"{sid}/" if sid else "")
    step_dir = os.path.join(root, f"step_{step}")
    files = set()
    for name, e in gm.leaves().items():
        if not name.startswith(prefix):
            continue
        for s in GlobalManifest.segments_of(e):
            if s.get("file"):
                files.add(os.path.join(step_dir, s["file"]))
            elif s.get("shard") is not None:
                files.add(os.path.join(step_dir,
                                       f"shard_{int(s['shard'])}.bin"))
    return sorted(files)


def tear_session_shard(root: str, step: int, sid: str,
                       frac: float = 0.5) -> str:
    """Tear (truncate) the first shard file carrying ``sid``'s snapshot —
    the torn-write-under-a-session fault.  Returns the damaged path."""
    files = session_shard_files(root, step, sid)
    if not files:
        raise FileNotFoundError(
            f"no shard files for session {sid!r} at step {step} in {root}")
    tear_file(files[0], frac=frac)
    return files[0]


# --------------------------------------------------------------------------
# I/O-path patches: stalled writers and dying partners
# --------------------------------------------------------------------------

@contextlib.contextmanager
def stalled_writer(delay_s: float, times: int = 1):
    """Delay the first ``times`` low-level shard writes by ``delay_s`` —
    a writer that is alive but slower than its peers expect."""
    from repro.checkpoint import store
    real = store._pwrite_all
    left = [int(times)]

    def slow(fd, buf, off):
        if left[0] > 0:
            left[0] -= 1
            time.sleep(delay_s)
        return real(fd, buf, off)

    store._pwrite_all = slow
    try:
        yield
    finally:
        store._pwrite_all = real


@contextlib.contextmanager
def partner_fetch_failure(times: int = 1, delete: bool = False):
    """Fail the next ``times`` L2 replica reads — the partner died (or
    its replica vanished) *during* the fetch.  ``delete`` also removes
    the replica payload, so retries cannot quietly succeed."""
    from repro.checkpoint import levels
    real = levels.PartnerStore.read_range
    left = [int(times)]

    def dying(self, step, src, entry, start, length):
        if left[0] > 0:
            left[0] -= 1
            if delete:
                d = self._src_dir(step, src)
                for n in (levels.REPLICA_PAYLOAD, levels.REPLICA_MANIFEST):
                    try:
                        os.unlink(os.path.join(d, n))
                    except OSError:
                        pass
            raise IOError(f"partner host {self.host} died during L2 fetch")
        return real(self, step, src, entry, start, length)

    levels.PartnerStore.read_range = dying
    try:
        yield
    finally:
        levels.PartnerStore.read_range = real


# --------------------------------------------------------------------------
# Env-driven arming (subprocess harnesses)
# --------------------------------------------------------------------------

def injector_from_env(env: str = FAULT_ENV) -> Optional[FaultInjector]:
    """Build an armed injector from ``$REPRO_FAULT`` or None.

    Format: ``point[@match][:hard]`` — e.g. ``after_replicate:hard``
    kills the process (``os._exit``) right after it lands its partner
    replica, ``before_commit_barrier`` raises ``HostKilled`` before the
    commit rendezvous.  Subprocess hosts arm this at manager
    construction, so the parent test chooses each child's failure by
    environment alone.
    """
    spec = os.environ.get(env, "").strip()
    if not spec:
        return None
    hard = spec.endswith(":hard")
    if hard:
        spec = spec[:-len(":hard")]
    point, _, match = spec.partition("@")
    inj = FaultInjector()
    inj.kill_at(point, match=match or None, hard=hard)
    return inj
