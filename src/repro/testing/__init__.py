"""Reusable test instrumentation (fault injection for the resilience
hierarchy lives in ``repro.testing.faults``)."""
