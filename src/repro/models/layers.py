"""Shared model layers: norms, projections, embeddings, RoPE/M-RoPE, FFNs.

Pure functions over nested-dict params (no NN framework): every ``init_*``
is ``jax.eval_shape``-safe (no data-dependent shapes), every ``apply`` is
jit/pjit-traceable.  Compute dtype and param dtype come from ArchConfig.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --- initializers ----------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --- norms -----------------------------------------------------------------

def init_norm(cfg, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype_of(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype_of(cfg.param_dtype))
    return p


def apply_norm(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm (gemma-style: scale is a +1 offset)
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
        y = y * (1.0 + p["scale"].astype(jnp.float32))
    return y.astype(x.dtype)


# --- rotary embeddings -----------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, T, H, D); positions: (B, T) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int] = (2, 1, 1)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, T, H, D); positions3: (B, T, 3) — temporal/height/width position
    ids.  Frequency channels are split across the three axes in proportion
    ``sections`` (t gets half, h/w a quarter each by default).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # (half,)
    total = sum(sections)
    bounds = np.cumsum([half * s // total for s in sections])
    chan_axis = np.zeros(half, np.int32)
    chan_axis[bounds[0]:bounds[1]] = 1
    chan_axis[bounds[1]:] = 2
    # angle per channel uses the position id of its assigned axis
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),  # (B, T, 3)
        jnp.broadcast_to(jnp.asarray(chan_axis)[None, None, :],
                         positions3.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # (B, T, half)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- FFN -------------------------------------------------------------------

def init_ffn(cfg, key, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    pdt = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn in ("swiglu", "geglu"):
        return {
            "wi": dense_init(k1, cfg.d_model, d_ff, pdt),
            "wg": dense_init(k2, cfg.d_model, d_ff, pdt),
            "wo": dense_init(k3, d_ff, cfg.d_model, pdt),
        }
    return {  # plain gelu MLP (whisper)
        "wi": dense_init(k1, cfg.d_model, d_ff, pdt),
        "wo": dense_init(k3, d_ff, cfg.d_model, pdt),
    }


def apply_ffn(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if cfg.ffn == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * h
    elif cfg.ffn == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(dt), approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ p["wo"].astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
