"""Attention: GQA/MQA (+ sliding window, logit softcap, M-RoPE) and
DeepSeek-style MLA with a compressed-latent KV cache for decode.

Three execution paths:
- ``_attend_full``: einsum attention for short sequences (smoke tests).
- ``_attend_chunked``: online-softmax attention, scan over q/kv blocks —
  the pure-jnp oracle of kernels/flash_attention and the path used for
  32k+ sequences (keeps compile-time memory at block granularity).
- kernels/flash_attention (Pallas, TPU): selected via ``set_attn_impl``.

Caches:
- global layers: ``{"k": (B, S, K, D), "v": (B, S, K, D)}``
- local (window) layers: same layout with S = window (ring buffer)
- MLA layers: ``{"c_kv": (B, S, R), "k_pe": (B, S, Dr)}`` — the latent
  cache; decode absorbs the up-projections (the paper's W_UK/W_UV trick).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (apply_mrope, apply_rope, dense_init,
                                 dtype_of, softcap)

_ATTN_IMPL = "auto"  # auto | full | chunked | pallas
_CHUNK_Q = 512
_CHUNK_KV = 512
_NEG = -2.3819763e38  # finite big-negative (bf16-safe), like flax


def set_attn_impl(impl: str) -> None:
    global _ATTN_IMPL
    assert impl in ("auto", "full", "chunked", "pallas")
    _ATTN_IMPL = impl


def get_attn_impl() -> str:
    return _ATTN_IMPL


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_attention(cfg, key) -> Dict[str, Any]:
    pdt = dtype_of(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 7)
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wq_a": dense_init(ks[0], cfg.d_model, m.q_lora_rank, pdt),
            "wq_b": dense_init(ks[1], m.q_lora_rank, cfg.n_heads * qk_dim, pdt),
            "wkv_a": dense_init(ks[2], cfg.d_model,
                                m.kv_lora_rank + m.qk_rope_head_dim, pdt),
            "wk_b": dense_init(ks[3], m.kv_lora_rank,
                               cfg.n_heads * m.qk_nope_head_dim, pdt),
            "wv_b": dense_init(ks[4], m.kv_lora_rank,
                               cfg.n_heads * m.v_head_dim, pdt),
            "wo": dense_init(ks[5], cfg.n_heads * m.v_head_dim, cfg.d_model, pdt),
        }
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, pdt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, pdt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, pdt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, pdt),
    }
    if cfg.qkv_bias:
        zeros = functools.partial(jnp.zeros, dtype=pdt)
        p["bq"] = zeros((cfg.n_heads * hd,))
        p["bk"] = zeros((cfg.n_kv_heads * hd,))
        p["bv"] = zeros((cfg.n_kv_heads * hd,))
    return p


# --------------------------------------------------------------------------
# core attention maths
# --------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, window: Optional[int], causal: bool):
    """(..., Tq, Tk) additive bias from position tensors (broadcastable)."""
    ok = jnp.ones(jnp.broadcast_shapes(q_pos.shape + (1,),
                                       k_pos.shape[:-1] + (1, k_pos.shape[-1])),
                  bool)
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def _attend_full(q, k, v, bias, scale, attn_cap):
    """q: (B,Tq,H,D) k: (B,Tk,K,D) v: (B,Tk,K,Dv) bias: (B,Tq,Tk) fp32."""
    B, Tq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = (q.astype(jnp.float32) * scale).reshape(B, Tq, K, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qf, k.astype(jnp.float32))
    s = softcap(s, attn_cap)
    s = s + bias[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, H, v.shape[-1]).astype(q.dtype)


def _attend_chunked(q, k, v, q_pos, k_pos, window, causal, scale, attn_cap,
                    chunk_q=_CHUNK_Q, chunk_kv=_CHUNK_KV):
    """Online-softmax attention, O(chunk²) live memory.

    q: (B,Tq,H,D); k/v: (B,Tk,K,D); q_pos: (B,Tq); k_pos: (B,Tk).
    """
    B, Tq, H, D = q.shape
    Tk, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    cq = min(chunk_q, Tq)
    ck = min(chunk_kv, Tk)
    nq, nk = -(-Tq // cq), -(-Tk // ck)
    pad_q, pad_k = nq * cq - Tq, nk * ck - Tk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)),
                        constant_values=np.iinfo(np.int32).max)

    qs = q.reshape(B, nq, cq, K, G, D).astype(jnp.float32) * scale
    ks = k.reshape(B, nk, ck, K, D).astype(jnp.float32)
    vs = v.reshape(B, nk, ck, K, Dv).astype(jnp.float32)
    qp = q_pos.reshape(B, nq, cq)
    kp = k_pos.reshape(B, nk, ck)

    def q_block(args):
        qb, qpb = args  # (B,cq,K,G,D), (B,cq)

        def kv_step(carry, blk):
            m, l, acc = carry
            kb, vb, kpb = blk  # (B,ck,K,D), (B,ck,K,D), (B,ck)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb)
            s = softcap(s, attn_cap)
            s = s + _mask_bias(qpb, kpb, window, causal)[:, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vb)
            return (m_new, l, acc), None

        m0 = jnp.full((B, K, G, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, K, G, cq), jnp.float32)
        a0 = jnp.zeros((B, K, G, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), kp.swapaxes(0, 1)))
        o = acc / jnp.maximum(l, 1e-37)[..., None]   # (B,K,G,cq,D)
        return o.transpose(0, 3, 1, 2, 4)            # (B,cq,K,G,D)

    outs = jax.lax.map(q_block, (qs.swapaxes(0, 1), qp.swapaxes(0, 1)))
    o = outs.swapaxes(0, 1).reshape(B, nq * cq, H, Dv)
    return o[:, :Tq].astype(q.dtype)


def _dispatch_attend(q, k, v, q_pos, k_pos, window, causal, scale, attn_cap):
    impl = _ATTN_IMPL
    Tq, Tk = q.shape[1], k.shape[1]
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, q_pos, k_pos, window=window,
                                      causal=causal, scale=scale,
                                      attn_cap=attn_cap)
    # Perf iteration 1 (EXPERIMENTS.md §Perf/phi4): the "full" path
    # materializes a (B,Tq,Tk) fp32 bias whose partial computation over the
    # model axis costs a ~Tq·Tk·4B all-reduce per layer per pass; blockwise
    # iota masks in the chunked path eliminate it.  Threshold 2048² keeps
    # einsum attention only where the bias is genuinely small.
    thr = _FULL_THRESHOLD
    if impl == "full" or (impl == "auto" and Tq * Tk <= thr * thr):
        bias = _mask_bias(q_pos, k_pos, window, causal)
        return _attend_full(q, k, v, bias, scale, attn_cap)
    return _attend_chunked(q, k, v, q_pos, k_pos, window, causal, scale, attn_cap)


_FULL_THRESHOLD = 2048  # baseline used 4096 (materialized (B,T,T) bias)


def set_full_attention_threshold(t: int) -> None:
    global _FULL_THRESHOLD
    _FULL_THRESHOLD = t


# --------------------------------------------------------------------------
# GQA layer entry points
# --------------------------------------------------------------------------

def _project_qkv(cfg, p, x, positions):
    dt = x.dtype
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, T, cfg.n_heads, hd)
    k = k.reshape(B, T, cfg.n_kv_heads, hd)
    v = v.reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.mrope:
        pos3 = positions if positions.ndim == 3 else jnp.repeat(positions[..., None], 3, -1)
        q = apply_mrope(q, pos3, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.rope_theta)
    else:
        pos = positions[..., 0] if positions.ndim == 3 else positions
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def attention_train(cfg, p, x, positions, *, window=None, causal=True):
    """Full-sequence self-attention (training / prefill without cache)."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    scale = cfg.resolved_head_dim ** -0.5
    pos = positions[..., 0] if positions.ndim == 3 else positions
    o = _dispatch_attend(q, k, v, pos, pos, window, causal, scale,
                         cfg.attn_softcap)
    B, T = x.shape[:2]
    return o.reshape(B, T, -1) @ p["wo"].astype(x.dtype)


def init_cache(cfg, batch: int, max_len: int, *, window=None, dtype=None):
    dt = dtype or dtype_of(cfg.dtype)
    hd = cfg.resolved_head_dim
    S = min(window, max_len) if window else max_len
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
            "k_pe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
        }
    return {
        "k": jnp.zeros((batch, S, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, S, cfg.n_kv_heads, hd), dt),
    }


def attention_decode(cfg, p, x, cache, pos, *, window=None):
    """One-token decode against a (possibly ring-buffered) cache.

    x: (B, 1, d); pos: scalar int32 — current position (same across batch,
    standard batched-decode contract).  Returns (out, new_cache).
    """
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    S = cache["k"].shape[1]
    slot = jnp.asarray((pos % S) if window else pos, jnp.int32)
    z = jnp.zeros((), jnp.int32)  # literal starts typed to match slot (x64)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (z, slot, z, z))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (z, slot, z, z))
    if window:
        # ring buffer: absolute position of slot s given write head at pos.
        # Slots not yet written (pos < S) resolve to negative positions —
        # mask them or they'd attend to zero vectors.
        idx = jnp.arange(S)
        k_pos = pos - ((slot - idx) % S)
        k_pos = jnp.where(k_pos >= 0, k_pos, np.iinfo(np.int32).max)
    else:
        idx = jnp.arange(S)
        k_pos = jnp.where(idx <= pos, idx, np.iinfo(np.int32).max)
    k_pos = jnp.broadcast_to(k_pos[None, :], (B, S)).astype(jnp.int32)
    scale = cfg.resolved_head_dim ** -0.5
    o = _attend_full(q, ck, cv,
                     _mask_bias(positions, k_pos, window, True),
                     scale, cfg.attn_softcap)
    out = o.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv}


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# --------------------------------------------------------------------------

def mla_train(cfg, p, x, positions):
    m = cfg.mla
    dt = x.dtype
    B, T, _ = x.shape
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim

    q = (x @ p["wq_a"].astype(dt)) @ p["wq_b"].astype(dt)
    q = q.reshape(B, T, H, qk_dim)
    q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]

    kv_a = x @ p["wkv_a"].astype(dt)
    c_kv, k_pe = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    k_nope = (c_kv @ p["wk_b"].astype(dt)).reshape(B, T, H, m.qk_nope_head_dim)
    v = (c_kv @ p["wv_b"].astype(dt)).reshape(B, T, H, m.v_head_dim)

    pos = positions[..., 0] if positions.ndim == 3 else positions
    q_pe = apply_rope(q_pe, pos, cfg.rope_theta)
    k_pe = apply_rope(k_pe[:, :, None, :], pos, cfg.rope_theta)  # shared head

    scale = qk_dim ** -0.5
    q_full = jnp.concatenate([q_nope, q_pe], -1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (B, T, H, m.qk_rope_head_dim))], -1)
    o = _dispatch_attend(q_full, k_full, v, pos, pos, None, True, scale, None)
    return o.reshape(B, T, H * m.v_head_dim) @ p["wo"].astype(dt)


def mla_decode(cfg, p, x, cache, pos):
    """Latent-cache decode: scores in the compressed space (absorbed W_UK);
    the cache stores (c_kv, k_pe) — (R + Dr) per token instead of
    2·H·head_dim.  This is the serving-side win scrutinized checkpoints
    inherit (cache suffix beyond ``pos`` is provably uncritical)."""
    m = cfg.mla
    dt = x.dtype
    B = x.shape[0]
    H = cfg.n_heads
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)

    q = (x @ p["wq_a"].astype(dt)) @ p["wq_b"].astype(dt)
    q = q.reshape(B, 1, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"].astype(dt)
    c_new, kpe_new = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    kpe_new = apply_rope(kpe_new[:, :, None, :], positions,
                         cfg.rope_theta)[:, :, 0, :]
    z = jnp.zeros((), jnp.int32)
    pos32 = jnp.asarray(pos, jnp.int32)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"],
                                        c_new.astype(cache["c_kv"].dtype),
                                        (z, pos32, z))
    k_pe = jax.lax.dynamic_update_slice(cache["k_pe"],
                                        kpe_new.astype(cache["k_pe"].dtype),
                                        (z, pos32, z))

    # absorb W_UK into the query: (B,1,H,R)
    wk_b = p["wk_b"].astype(dt).reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bthd,rhd->bthr", q_nope, wk_b)

    S = c_kv.shape[1]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bthr,bsr->bhts", q_lat.astype(jnp.float32),
                    c_kv.astype(jnp.float32))
         + jnp.einsum("bthd,bsd->bhts", q_pe.astype(jnp.float32),
                      k_pe.astype(jnp.float32))) * scale
    idx = jnp.arange(S)
    mask = jnp.where(idx <= pos, 0.0, _NEG)[None, None, None, :]
    prob = jax.nn.softmax(s + mask, axis=-1)
    o_lat = jnp.einsum("bhts,bsr->bthr", prob,
                       c_kv.astype(jnp.float32))          # (B,1,H,R)
    wv_b = p["wv_b"].astype(dt).reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bthr,rhd->bthd", o_lat.astype(dt), wv_b)
    out = o.reshape(B, 1, H * m.v_head_dim) @ p["wo"].astype(dt)
    return out, {"c_kv": c_kv, "k_pe": k_pe}
