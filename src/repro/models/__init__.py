"""Model substrate: one configurable stack for all assigned architectures."""

from repro.models.model import (
    count_params,
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.attention import set_attn_impl, get_attn_impl

__all__ = [
    "count_params", "decode_step", "init_cache", "init_params",
    "loss_fn", "prefill", "set_attn_impl", "get_attn_impl",
]
