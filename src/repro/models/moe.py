"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

Dispatch is the static-shape sort/gather/scatter formulation (no (N,E,C)
one-hot tensors): tokens are argsorted by expert id, given a slot within
their expert's capacity buffer, processed by a batched per-expert einsum
(`ecd,edf->ecf` — EP-shardable over the leading expert axis), and combined
back with router weights.  Overflow beyond capacity is dropped (classic
capacity-factor straggler mitigation: step time never depends on the most
oversubscribed expert).

Shared experts (DeepSeek) run densely on every token.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of

CAPACITY_FACTOR = 1.25

_DISPATCH = "row"  # row (optimized, row-local sort) | global (paper baseline)


def set_dispatch(mode: str) -> None:
    global _DISPATCH
    assert mode in ("row", "global")
    _DISPATCH = mode


def init_moe(cfg, key) -> Dict[str, Any]:
    m = cfg.moe
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    E, d, f = m.num_experts, cfg.d_model, m.d_expert

    def stack(k, din, dout, n):
        keys = jax.random.split(k, n)
        return jax.vmap(lambda kk: dense_init(kk, din, dout, pdt))(keys)

    p = {
        "router": dense_init(ks[0], d, E, pdt),
        "wi": stack(ks[1], d, f, E),
        "wg": stack(ks[2], d, f, E),
        "wo": stack(ks[3], f, d, E),
    }
    if m.num_shared:
        p["shared"] = {
            "wi": stack(ks[4], d, f, m.num_shared),
            "wg": stack(jax.random.fold_in(ks[4], 1), d, f, m.num_shared),
            "wo": stack(jax.random.fold_in(ks[4], 2), f, d, m.num_shared),
        }
    return p


def _experts_ffn(wi, wg, wo, x):  # x: (E, C, d)
    h = jnp.einsum("ecd,edf->ecf", x, wi.astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", x, wg.astype(x.dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(x.dtype))


def apply_moe(cfg, p, x: jnp.ndarray, *,
              capacity_factor: float = CAPACITY_FACTOR, train: bool = False):
    """x: (B, T, d) → (out (B, T, d), aux_loss scalar).

    ``train`` gates capacity dropping.  Dropping is a *training-throughput*
    device (step time never depends on the most oversubscribed expert), but
    it makes a token's output depend on the row length and on every other
    token's routing: the same prefix run at T and T+1 tokens routes
    differently, so prefill+decode could never reproduce the forward pass
    bit-for-bit (and a migrated decode could never match an uninterrupted
    one).  Inference therefore runs dropless — capacity = S, every routed
    slot is processed — which is also what serving stacks do in practice.
    Long-prompt prefill should chunk T if the (B, E, S, d) dropless buffer
    gets large.

    Perf iteration (EXPERIMENTS.md §Perf/olmoe): dispatch is **row-local**.
    A global argsort over B·T·K slots forces XLA to reshard the whole token
    stream (multi-TB collective storms at pod scale); sorting each batch
    row independently keeps every sort/scatter on the row's own data shard,
    and the only cross-device movement left is the unavoidable EP
    dispatch/combine of the (B, E, C, d) buffers between the data and
    model(expert) axes.  Per-row capacity C = T·K/E·cf (slightly higher
    drop variance than global capacity — straggler mitigation unchanged).
    """
    m = cfg.moe
    B, T, d = x.shape
    E, K = m.num_experts, m.top_k
    S = T * K                                             # slots per row

    if _DISPATCH == "global":
        return _apply_moe_global(cfg, p, x, capacity_factor, train)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                # (B,T,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (Switch-style) -------------------------
    me = probs.mean((0, 1))                               # (E,)
    rows = jnp.arange(B)[:, None]
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (B * S)
    aux = m.aux_loss_coef * E * jnp.sum(me * ce)

    # --- row-local sort-based dispatch ----------------------------------
    C = max(1, int(S / E * capacity_factor)) if train else S
    flat_e = top_e.reshape(B, S)
    order = jnp.argsort(flat_e, axis=1)                   # per-row, local
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    tok_sorted = order // K                               # (B,S)
    counts = jnp.zeros((B, E), jnp.int32).at[rows, flat_e].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts          # (B,E)
    slot = jnp.arange(S)[None, :] - jnp.take_along_axis(starts, e_sorted, 1)
    keep = slot < C

    xs = jnp.take_along_axis(
        x, tok_sorted[..., None], axis=1)                 # (B,S,d) row-local
    buf = jnp.zeros((B, E, C, d), x.dtype)
    buf = buf.at[rows[..., None].repeat(S, 1)[..., 0],
                 jnp.where(keep, e_sorted, E - 1),
                 jnp.where(keep, slot, C - 1)].set(
        jnp.where(keep[..., None], xs, 0.0), mode="drop")

    # EP compute: experts batched over (B, E) — B stays on the data axis,
    # E on the model axis; the buf reshard is the EP all-to-all.
    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype))

    gathered = out_buf[rows[..., None].repeat(S, 1)[..., 0],
                       e_sorted, jnp.clip(slot, 0, C - 1)]   # (B,S,d)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    inv = jnp.argsort(order, axis=1)                      # undo row sort
    contrib = jnp.take_along_axis(gathered, inv[..., None], axis=1)
    contrib = contrib.reshape(B, T, K, d)
    out = jnp.einsum("btkd,btk->btd", contrib.astype(jnp.float32),
                     top_w).astype(x.dtype)

    if m.num_shared:
        sh = p["shared"]
        xf = x.reshape(B * T, d)
        s = _experts_ffn(sh["wi"], sh["wg"], sh["wo"],
                         jnp.broadcast_to(xf, (m.num_shared, B * T, d)))
        out = out + s.sum(0).astype(x.dtype).reshape(B, T, d)

    return out, aux


def _apply_moe_global(cfg, p, x, capacity_factor, train=False):
    """Baseline dispatch (perf-log 'before'): one global argsort over all
    B·T·K slots — correct, but the global sort/scatter reshards the whole
    token stream across the mesh (§Perf/olmoe)."""
    m = cfg.moe
    B, T, d = x.shape
    E, K = m.num_experts, m.top_k
    N = B * T
    xf = x.reshape(N, d)
    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (N * K)
    aux = m.aux_loss_coef * E * jnp.sum(me * ce)

    C = max(1, int(N * K / E * capacity_factor)) if train else N * K
    flat_e = top_e.reshape(N * K)
    order = jnp.argsort(flat_e)
    e_sorted = flat_e[order]
    tok_sorted = order // K
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(N * K) - starts[e_sorted]
    keep = slot < C
    xs = xf[tok_sorted]
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[jnp.where(keep, e_sorted, E - 1),
                 jnp.where(keep, slot, C - 1)].set(
        jnp.where(keep[:, None], xs, 0.0), mode="drop")
    out_buf = _experts_ffn(p["wi"], p["wg"], p["wo"], buf)
    gathered = out_buf[e_sorted, jnp.clip(slot, 0, C - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    inv = jnp.argsort(order)
    contrib = gathered[inv].reshape(N, K, d)
    out = jnp.einsum("nkd,nk->nd", contrib.astype(jnp.float32),
                     top_w).astype(x.dtype)
    if m.num_shared:
        sh = p["shared"]
        s = _experts_ffn(sh["wi"], sh["wg"], sh["wo"],
                         jnp.broadcast_to(xf, (m.num_shared, N, d)))
        out = out + s.sum(0).astype(x.dtype)
    return out.reshape(B, T, d), aux
