"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM + sLSTM (xLSTM).

Training uses ``lax.associative_scan`` for the diagonal RG-LRU recurrence
(log-depth, TPU-friendly; kernels/lru_scan is the blocked Pallas version)
and ``lax.scan`` for the matrix/scalar LSTM cells.  Decode carries an
explicit recurrent state — the constant-size serving cache whose
scrutinized checkpoint is tiny (see DESIGN.md §6).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of

_CONV_W = 4  # temporal conv width (griffin / xlstm)
_LRU_C = 8.0


# --------------------------------------------------------------------------
# RG-LRU (griffin) block
# --------------------------------------------------------------------------

def init_rglru(cfg, key) -> Dict[str, Any]:
    pdt = dtype_of(cfg.param_dtype)
    d, r = cfg.d_model, cfg.lru_dim or cfg.d_model
    ks = jax.random.split(key, 7)
    # Λ init so that a = sigmoid(Λ)^(c) spreads over (0.9, 0.999)
    lam = jnp.log(jnp.expm1(
        jnp.linspace(0.9, 0.999, r) ** (1.0 / _LRU_C))).astype(pdt)
    return {
        "w_in": dense_init(ks[0], d, r, pdt),
        "w_gate": dense_init(ks[1], d, r, pdt),
        "conv": (jax.random.normal(ks[2], (_CONV_W, r), jnp.float32) * 0.1).astype(pdt),
        "w_a": dense_init(ks[3], r, r, pdt),
        "w_x": dense_init(ks[4], r, r, pdt),
        "lambda": lam,
        "w_out": dense_init(ks[5], r, d, pdt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, r), w: (W, r) depthwise causal conv."""
    W = w.shape[0]
    out = x * w[W - 1]
    for j in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[W - 1 - j]
    return out


def _lru_scan_assoc(log_a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t over axis 1, via associative scan."""

    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    la, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_train(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    u = x @ p["w_in"].astype(dt)                       # (B,T,r)
    u = _causal_conv(u, p["conv"].astype(dt))
    r_gate = jax.nn.sigmoid((u @ p["w_a"].astype(dt)).astype(jnp.float32))
    i_gate = jax.nn.sigmoid((u @ p["w_x"].astype(dt)).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r_gate
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i_gate * u.astype(jnp.float32))
    h = _lru_scan_assoc(log_a, b).astype(dt)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt), approximate=True)
    return (h * gate) @ p["w_out"].astype(dt)


def rglru_init_state(cfg, batch: int, dtype=None):
    dt = dtype or dtype_of(cfg.dtype)
    r = cfg.lru_dim or cfg.d_model
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, _CONV_W - 1, r), dt)}


def rglru_decode(cfg, p, x: jnp.ndarray, state) -> Tuple[jnp.ndarray, Any]:
    """x: (B, 1, d)."""
    dt = x.dtype
    u = (x @ p["w_in"].astype(dt))[:, 0]               # (B,r)
    hist = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # (B,W,r)
    w = p["conv"].astype(dt)
    u_c = jnp.einsum("bwr,wr->br", hist, w)
    r_gate = jax.nn.sigmoid((u_c @ p["w_a"].astype(dt)).astype(jnp.float32))
    i_gate = jax.nn.sigmoid((u_c @ p["w_x"].astype(dt)).astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i_gate * u_c.astype(jnp.float32))
    h = a * state["h"] + b
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"].astype(dt), approximate=True)
    out = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    return out[:, None], {"h": h, "conv": hist[:, 1:]}


# --------------------------------------------------------------------------
# mLSTM (xLSTM) block — matrix memory, exponential gating with stabilizer
# --------------------------------------------------------------------------

def init_mlstm(cfg, key) -> Dict[str, Any]:
    pdt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    H = cfg.n_heads
    hd = (cfg.lru_dim or d) // H
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d, H * hd, pdt),
        "wk": dense_init(ks[1], d, H * hd, pdt),
        "wv": dense_init(ks[2], d, H * hd, pdt),
        "wi": dense_init(ks[3], d, H, pdt),
        "wf": dense_init(ks[4], d, H, pdt),
        "wz": dense_init(ks[5], d, H * hd, pdt),   # output gate branch
        "wo": dense_init(ks[6], H * hd, d, pdt),
    }


def _mlstm_qkvif(cfg, p, x):
    dt = x.dtype
    B, T, _ = x.shape
    H = cfg.n_heads
    hd = (cfg.lru_dim or cfg.d_model) // H
    q = (x @ p["wq"].astype(dt)).reshape(B, T, H, hd).astype(jnp.float32)
    k = (x @ p["wk"].astype(dt)).reshape(B, T, H, hd).astype(jnp.float32)
    v = (x @ p["wv"].astype(dt)).reshape(B, T, H, hd).astype(jnp.float32)
    logi = (x @ p["wi"].astype(dt)).astype(jnp.float32)          # (B,T,H)
    logf = jax.nn.log_sigmoid((x @ p["wf"].astype(dt)).astype(jnp.float32))
    k = k / jnp.sqrt(jnp.float32(hd))
    return q, k, v, logi, logf


def _mlstm_step(carry, inp):
    C, n, m = carry            # (B,H,hd,hd), (B,H,hd), (B,H)
    q, k, v, logi, logf = inp  # (B,H,hd) ×3, (B,H) ×2
    m_new = jnp.maximum(logf + m, logi)
    i_p = jnp.exp(logi - m_new)[..., None]
    f_p = jnp.exp(logf + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_p * n + i_p * k
    h_num = jnp.einsum("bhij,bhj->bhi", C, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = h_num / h_den[..., None]
    return (C, n, m_new), h


def mlstm_train(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    B, T, d = x.shape
    H = cfg.n_heads
    hd = (cfg.lru_dim or d) // H
    q, k, v, logi, logf = _mlstm_qkvif(cfg, p, x)
    init = (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32))
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          logi.swapaxes(0, 1), logf.swapaxes(0, 1))
    _, hs = jax.lax.scan(_mlstm_step, init, xs)
    h = hs.swapaxes(0, 1).reshape(B, T, H * hd).astype(x.dtype)
    z = jax.nn.silu(x @ p["wz"].astype(x.dtype))
    return (h * z) @ p["wo"].astype(x.dtype)


def mlstm_init_state(cfg, batch: int):
    H = cfg.n_heads
    hd = (cfg.lru_dim or cfg.d_model) // H
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def mlstm_decode(cfg, p, x, state):
    q, k, v, logi, logf = _mlstm_qkvif(cfg, p, x)      # T = 1
    carry = (state["C"], state["n"], state["m"])
    carry, h = _mlstm_step(carry, (q[:, 0], k[:, 0], v[:, 0],
                                   logi[:, 0], logf[:, 0]))
    B = x.shape[0]
    h = h.reshape(B, 1, -1).astype(x.dtype)
    z = jax.nn.silu(x @ p["wz"].astype(x.dtype))
    out = (h * z) @ p["wo"].astype(x.dtype)
    return out, {"C": carry[0], "n": carry[1], "m": carry[2]}


# --------------------------------------------------------------------------
# sLSTM (xLSTM) block — scalar memory with recurrent head mixing
# --------------------------------------------------------------------------

def init_slstm(cfg, key) -> Dict[str, Any]:
    pdt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    H = cfg.n_heads
    hd = (cfg.lru_dim or d) // H
    ks = jax.random.split(key, 9)
    p = {"wo": dense_init(ks[8], H * hd, d, pdt)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = dense_init(ks[i], d, H * hd, pdt)
        # recurrent mixing is block-diagonal per head
        p[f"r{g}"] = (jax.random.normal(ks[4 + i], (H, hd, hd), jnp.float32)
                      / jnp.sqrt(jnp.float32(hd))).astype(pdt)
    return p


def _slstm_step(p32, carry, inp):
    c, n, m, h = carry          # all (B,H,hd)
    xz, xi, xf, xo = inp

    def rec(name, hh):
        return jnp.einsum("bhj,hjk->bhk", hh, p32[name])

    z = jnp.tanh(xz + rec("rz", h))
    logi = xi + rec("ri", h)
    logf = jax.nn.log_sigmoid(xf + rec("rf", h))
    o = jax.nn.sigmoid(xo + rec("ro", h))
    m_new = jnp.maximum(logf + m, logi)
    i_p = jnp.exp(logi - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h = o * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h), h


def _slstm_inputs(cfg, p, x):
    dt = x.dtype
    B, T, _ = x.shape
    H = cfg.n_heads
    hd = (cfg.lru_dim or cfg.d_model) // H

    def proj(name):
        return (x @ p[name].astype(dt)).reshape(B, T, H, hd).astype(jnp.float32)

    return proj("wz"), proj("wi"), proj("wf"), proj("wo")


def slstm_train(cfg, p, x: jnp.ndarray) -> jnp.ndarray:
    B, T, d = x.shape
    H = cfg.n_heads
    hd = (cfg.lru_dim or d) // H
    xz, xi, xf, xo = _slstm_inputs(cfg, p, x)
    p32 = {k: p[k].astype(jnp.float32) for k in ("rz", "ri", "rf", "ro")}
    init = tuple(jnp.zeros((B, H, hd), jnp.float32) for _ in range(3)) + (
        jnp.zeros((B, H, hd), jnp.float32),)
    init = (init[0], init[1], jnp.full((B, H, hd), -1e30, jnp.float32), init[3])
    xs = tuple(a.swapaxes(0, 1) for a in (xz, xi, xf, xo))
    _, hs = jax.lax.scan(lambda c, i: _slstm_step(p32, c, i), init, xs)
    h = hs.swapaxes(0, 1).reshape(B, T, H * hd).astype(x.dtype)
    return h @ p["wo"].astype(x.dtype)


def slstm_init_state(cfg, batch: int):
    H = cfg.n_heads
    hd = (cfg.lru_dim or cfg.d_model) // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, H, hd), -1e30, jnp.float32),
            "h": z}


def slstm_decode(cfg, p, x, state):
    xz, xi, xf, xo = _slstm_inputs(cfg, p, x)
    p32 = {k: p[k].astype(jnp.float32) for k in ("rz", "ri", "rf", "ro")}
    carry = (state["c"], state["n"], state["m"], state["h"])
    carry, h = _slstm_step(p32, carry, (xz[:, 0], xi[:, 0], xf[:, 0], xo[:, 0]))
    B = x.shape[0]
    out = h.reshape(B, 1, -1).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
