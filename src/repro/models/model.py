"""Model assembly: layer planning, scan-over-layers, train/prefill/decode.

One code path serves all 10 assigned architectures; an ``ArchConfig`` fully
determines block flavours.  Layers are planned into homogeneous *segments*
(cyclic pattern units or maximal runs) so parameters stack and
``lax.scan`` runs one compiled block body per segment — this is what keeps
61-layer/46-layer archs compilable and is remat-friendly.

Batch contracts (see launch/specs.py):
  train:   {"tokens": (B,T) i32, "labels": (B,T) i32, ["positions"],
            ["patch_embeds" (B,P,d) for vlm], ["frames" (B,F,d) audio]}
  prefill: same minus labels → returns (last-position logits, cache)
  decode:  tokens (B,1) + cache + pos scalar → (logits, new cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.layers import (apply_norm, apply_ffn, dtype_of, embed_init,
                                 init_ffn, init_norm, softcap)

# --------------------------------------------------------------------------
# layer planning
# --------------------------------------------------------------------------

Kind = Tuple[str, str]  # (flavour: g|l|r|m|s, ffn: d|e|n)


def layer_kinds(cfg) -> List[Kind]:
    kinds = []
    for l in range(cfg.n_layers):
        fl = cfg.pattern_at(l)
        if cfg.moe_at(l):
            f = "e"
        elif cfg.d_ff and cfg.d_ff > 0:
            f = "d"
        else:
            f = "n"
        kinds.append((fl, f))
    return kinds


def plan_segments(kinds: List[Kind]) -> List[Tuple[Tuple[Kind, ...], int]]:
    """Segment layers into (unit, count) scans: cyclic unit detection first,
    maximal identical runs as fallback."""
    n = len(kinds)
    for ulen in range(1, 9):
        cnt = n // ulen
        if cnt < 2:
            break
        if all(kinds[i] == kinds[i % ulen] for i in range(cnt * ulen)):
            segs = [(tuple(kinds[:ulen]), cnt)]
            if n % ulen:
                segs.append((tuple(kinds[cnt * ulen:]), 1))
            return segs
    segs: List[Tuple[Tuple[Kind, ...], int]] = []
    i = 0
    while i < n:
        j = i
        while j < n and kinds[j] == kinds[i]:
            j += 1
        segs.append(((kinds[i],), j - i))
        i = j
    return segs


# --------------------------------------------------------------------------
# block init/apply
# --------------------------------------------------------------------------

def _init_mixer(cfg, flavour: str, key):
    if flavour in ("g", "l"):
        return attn.init_attention(cfg, key)
    if flavour == "r":
        return rec.init_rglru(cfg, key)
    if flavour == "m":
        return rec.init_mlstm(cfg, key)
    if flavour == "s":
        return rec.init_slstm(cfg, key)
    raise ValueError(flavour)


def init_block(cfg, kind: Kind, key, cross: bool = False):
    fl, ff = kind
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {
        "norm1": init_norm(cfg),
        "mixer": _init_mixer(cfg, fl, ks[0]),
    }
    if cfg.post_norm:
        p["norm1_post"] = init_norm(cfg)
    if cross:
        p["norm_x"] = init_norm(cfg)
        p["cross"] = attn.init_attention(cfg, ks[1])
    if ff == "d":
        p["norm2"] = init_norm(cfg)
        p["ffn"] = init_ffn(cfg, ks[2])
        if cfg.post_norm:
            p["norm2_post"] = init_norm(cfg)
    elif ff == "e":
        p["norm2"] = init_norm(cfg)
        p["moe"] = moe_mod.init_moe(cfg, ks[3])
        if cfg.post_norm:
            p["norm2_post"] = init_norm(cfg)
    return p


def _mixer_train(cfg, kind, p, x, positions):
    fl = kind[0]
    if fl in ("g", "l"):
        window = cfg.window if fl == "l" else None
        if cfg.mla is not None:
            return attn.mla_train(cfg, p["mixer"], x, positions)
        return attn.attention_train(cfg, p["mixer"], x, positions,
                                    window=window)
    if fl == "r":
        return rec.rglru_train(cfg, p["mixer"], x)
    if fl == "m":
        return rec.mlstm_train(cfg, p["mixer"], x)
    return rec.slstm_train(cfg, p["mixer"], x)


def apply_block_train(cfg, kind, p, x, positions, enc_out=None,
                      enc_positions=None, train=False):
    h = apply_norm(cfg, p["norm1"], x)
    h = _mixer_train(cfg, kind, p, h, positions)
    if cfg.post_norm:
        h = apply_norm(cfg, p["norm1_post"], h)
    x = x + h
    if "cross" in p:
        h = apply_norm(cfg, p["norm_x"], x)
        h = _cross_attend(cfg, p["cross"], h, enc_out, positions, enc_positions)
        x = x + h
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p or "moe" in p:
        h = apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            h, aux = moe_mod.apply_moe(cfg, p["moe"], h, train=train)
        else:
            h = apply_ffn(cfg, p["ffn"], h)
        if cfg.post_norm:
            h = apply_norm(cfg, p["norm2_post"], h)
        x = x + h
    return x, aux


def _cross_attend(cfg, p, x, enc_out, positions, enc_positions):
    """Encoder-decoder cross attention (whisper); no causal mask."""
    dt = x.dtype
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(dt)).reshape(B, T, cfg.n_heads, hd)
    S = enc_out.shape[1]
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
    bias = jnp.zeros((B, T, S), jnp.float32)
    o = attn._attend_full(q, k, v, bias, hd ** -0.5, None)
    return o.reshape(B, T, -1) @ p["wo"].astype(dt)


# --- decode ----------------------------------------------------------------

def init_layer_cache(cfg, kind: Kind, batch: int, max_len: int,
                     cross_len: int = 0):
    fl = kind[0]
    c: Dict[str, Any] = {}
    if fl in ("g", "l"):
        window = cfg.window if fl == "l" else None
        c.update(attn.init_cache(cfg, batch, max_len, window=window))
    elif fl == "r":
        c.update(rec.rglru_init_state(cfg, batch))
    elif fl == "m":
        c.update(rec.mlstm_init_state(cfg, batch))
    elif fl == "s":
        c.update(rec.slstm_init_state(cfg, batch))
    if cross_len:
        hd = cfg.resolved_head_dim
        dt = dtype_of(cfg.dtype)
        c["xk"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), dt)
        c["xv"] = jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd), dt)
    return c


def apply_block_decode(cfg, kind, p, x, cache, pos):
    fl = kind[0]
    h = apply_norm(cfg, p["norm1"], x)
    new_cache = dict(cache)
    if fl in ("g", "l"):
        window = cfg.window if fl == "l" else None
        if cfg.mla is not None:
            h, upd = attn.mla_decode(cfg, p["mixer"], h,
                                     {k: cache[k] for k in ("c_kv", "k_pe")},
                                     pos)
        else:
            h, upd = attn.attention_decode(cfg, p["mixer"], h,
                                           {k: cache[k] for k in ("k", "v")},
                                           pos, window=window)
        new_cache.update(upd)
    elif fl == "r":
        h, upd = rec.rglru_decode(cfg, p["mixer"], h,
                                  {k: cache[k] for k in ("h", "conv")})
        new_cache.update(upd)
    elif fl == "m":
        h, upd = rec.mlstm_decode(cfg, p["mixer"], h,
                                  {k: cache[k] for k in ("C", "n", "m")})
        new_cache.update(upd)
    else:
        h, upd = rec.slstm_decode(cfg, p["mixer"], h,
                                  {k: cache[k] for k in ("c", "n", "m", "h")})
        new_cache.update(upd)
    if cfg.post_norm:
        h = apply_norm(cfg, p["norm1_post"], h)
    x = x + h
    if "cross" in p:
        h = apply_norm(cfg, p["norm_x"], x)
        B = x.shape[0]
        hd = cfg.resolved_head_dim
        q = (h @ p["cross"]["wq"].astype(h.dtype)).reshape(B, 1, cfg.n_heads, hd)
        bias = jnp.zeros((B, 1, cache["xk"].shape[1]), jnp.float32)
        o = attn._attend_full(q, cache["xk"], cache["xv"], bias, hd ** -0.5, None)
        x = x + o.reshape(B, 1, -1) @ p["cross"]["wo"].astype(h.dtype)
    if "ffn" in p or "moe" in p:
        h = apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            h, _ = moe_mod.apply_moe(cfg, p["moe"], h)
        else:
            h = apply_ffn(cfg, p["ffn"], h)
        if cfg.post_norm:
            h = apply_norm(cfg, p["norm2_post"], h)
        x = x + h
    return x, new_cache


# --------------------------------------------------------------------------
# whole-model init
# --------------------------------------------------------------------------

def init_params(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    pdt = dtype_of(cfg.param_dtype)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, pdt),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[1], cfg.vocab, cfg.d_model, pdt).T

    segs = plan_segments(layer_kinds(cfg))
    cross = cfg.enc_dec
    seg_params = {}
    for si, (unit, count) in enumerate(segs):
        def init_one(k, unit=unit):
            uks = jax.random.split(k, len(unit))
            return {f"u{ui}": init_block(cfg, kind, uks[ui], cross=cross)
                    for ui, kind in enumerate(unit)}

        keys = jax.random.split(jax.random.fold_in(ks[2], si), count)
        seg_params[f"seg{si}"] = jax.vmap(init_one)(keys)
    params["segments"] = seg_params

    if cfg.enc_dec:
        enc_kinds = [("g", "d")] * cfg.n_encoder_layers

        def init_enc(k):
            return {"u0": init_block(cfg, ("g", "d"), k, cross=False)}

        keys = jax.random.split(ks[3], cfg.n_encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(init_enc)(keys),
            "final_norm": init_norm(cfg),
        }
    return params


# --------------------------------------------------------------------------
# embeddings / positions
# --------------------------------------------------------------------------

def _embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens].astype(dtype_of(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def _input_sequence(cfg, params, batch):
    """tokens (+ modality stubs) → (x, positions, text_offset)."""
    x = _embed_tokens(cfg, params, batch["tokens"])
    B, T = batch["tokens"].shape
    offset = 0
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        offset = pe.shape[1]
    L = x.shape[1]
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    return x, positions, offset


# --------------------------------------------------------------------------
# forward: train loss
# --------------------------------------------------------------------------

_LOSS_CHUNK = 512


def lm_head_logits(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return softcap(logits, cfg.logit_softcap)


def _chunked_loss(cfg, params, h, labels, mask):
    """Cross-entropy without materializing (B, T, V) at once."""
    B, T, d = h.shape
    c = min(_LOSS_CHUNK, T)
    n = -(-T // c)
    pad = n * c - T
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(B, n, c, d).swapaxes(0, 1)
    lc = labels.reshape(B, n, c).swapaxes(0, 1)
    mc = mask.reshape(B, n, c).swapaxes(0, 1)

    def step(tot, inp):
        hh, ll, mm = inp
        logits = lm_head_logits(cfg, params, hh).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return tot + nll.sum(), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc, mc))
    return tot / jnp.maximum(mask.sum(), 1.0)


def _run_encoder(cfg, params, frames):
    x = frames.astype(dtype_of(cfg.dtype))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, p_l):
        h, _ = apply_block_train(cfg, ("g", "d"), p_l["u0"], carry, positions)
        # encoder is bidirectional: rerun mixer non-causally is handled by
        # attention flavour below — see note.
        return h, None

    # Bidirectional: temporarily run attention without the causal mask by
    # passing causal=False through a local closure.
    def enc_block(x, p_l):
        h = apply_norm(cfg, p_l["norm1"], x)
        h = attn.attention_train(cfg, p_l["mixer"], h, positions, causal=False)
        x = x + h
        h = apply_norm(cfg, p_l["norm2"], x)
        x = x + apply_ffn(cfg, p_l["ffn"], h)
        return x

    def scan_body(carry, p_l):
        return enc_block(carry, p_l["u0"]), None

    x, _ = jax.lax.scan(scan_body, x, params["encoder"]["blocks"])
    return apply_norm(cfg, params["encoder"]["final_norm"], x), positions


_SEQ_SHARD_RESIDUAL = False  # perf knob: Megatron-style sequence parallelism


def set_seq_shard_residual(on: bool) -> None:
    global _SEQ_SHARD_RESIDUAL
    _SEQ_SHARD_RESIDUAL = on


def _sp_constraint(h):
    """Shard the residual stream's sequence dim over the model axis between
    blocks (norms/elementwise run on T/tp, converts XLA's per-layer
    all-reduce into reduce-scatter + all-gather)."""
    if not _SEQ_SHARD_RESIDUAL:
        return h
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(h, P(None, "model", None))
    except Exception:  # no mesh in scope (single-device tests)
        return h


def _run_segments(cfg, params, x, positions, enc_out=None, enc_positions=None,
                  remat=None, train=False):
    segs = plan_segments(layer_kinds(cfg))
    aux_total = jnp.zeros((), jnp.float32)
    use_remat = cfg.remat if remat is None else remat
    for si, (unit, count) in enumerate(segs):
        stacked = params["segments"][f"seg{si}"]

        def body(carry, p_l, unit=unit):
            h, aux = carry
            for ui, kind in enumerate(unit):
                h = _sp_constraint(h)
                h, a = apply_block_train(cfg, kind, p_l[f"u{ui}"], h,
                                         positions, enc_out, enc_positions,
                                         train=train)
                aux = aux + a
            return (h, aux), None

        if use_remat:
            # Perf iteration 2 (EXPERIMENTS.md §Perf/phi4): saving matmul
            # outputs means the backward pass does not replay the forward's
            # row-parallel all-reduces (TP collectives) or the matmul FLOPs;
            # only cheap elementwise work is recomputed.
            policy = (None if _REMAT_POLICY == "full" else
                      jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            body = jax.checkpoint(body, prevent_cse=False, policy=policy)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
    return x, aux_total


_REMAT_POLICY = "dots"  # dots (optimized) | full (baseline everything-remat)


def set_remat_policy(mode: str) -> None:
    global _REMAT_POLICY
    assert mode in ("dots", "full")
    _REMAT_POLICY = mode


def loss_fn(cfg, params, batch):
    """Mean next-token cross-entropy (+ MoE aux)."""
    x, positions, offset = _input_sequence(cfg, params, batch)
    enc_out = enc_pos = None
    if cfg.enc_dec:
        enc_out, enc_pos = _run_encoder(cfg, params, batch["frames"])
    # train=True turns on MoE capacity dropping (a throughput device that is
    # row-length dependent, so eval/prefill/decode paths run dropless).
    x, aux = _run_segments(cfg, params, x, positions, enc_out, enc_pos,
                           train=True)
    x = apply_norm(cfg, params["final_norm"], x)
    if offset:
        x = x[:, offset:]
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return _chunked_loss(cfg, params, x, labels,
                         mask.astype(jnp.float32)) + aux


# --------------------------------------------------------------------------
# forward: prefill & decode
# --------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int):
    segs = plan_segments(layer_kinds(cfg))
    cross_len = cfg.encoder_len if cfg.enc_dec else 0
    caches = {}
    for si, (unit, count) in enumerate(segs):
        def one(_, unit=unit):
            return {f"u{ui}": init_layer_cache(cfg, kind, batch, max_len,
                                               cross_len)
                    for ui, kind in enumerate(unit)}

        caches[f"seg{si}"] = jax.vmap(one)(jnp.arange(count))
    return caches


def prefill(cfg, params, batch, max_len: int):
    """Run the prompt through the model; return (last logits, cache at
    position T).  Implemented as train-mode forward + cache capture."""
    x, positions, offset = _input_sequence(cfg, params, batch)
    enc_out = enc_pos = None
    if cfg.enc_dec:
        enc_out, enc_pos = _run_encoder(cfg, params, batch["frames"])
    B, T = x.shape[0], x.shape[1]
    max_len = max(max_len, T)  # modality stubs may extend the sequence

    segs = plan_segments(layer_kinds(cfg))
    caches = {}
    for si, (unit, count) in enumerate(segs):
        stacked = params["segments"][f"seg{si}"]

        def body(h, p_l, unit=unit):
            cache_l = {}
            for ui, kind in enumerate(unit):
                h, c = _prefill_block(cfg, kind, p_l[f"u{ui}"], h, positions,
                                      max_len, enc_out, enc_pos)
                cache_l[f"u{ui}"] = c
            return h, cache_l

        x, caches[f"seg{si}"] = jax.lax.scan(body, x, stacked)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head_logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def _prefill_block(cfg, kind, p, x, positions, max_len, enc_out, enc_pos):
    """Block forward that also captures the decode cache."""
    fl = kind[0]
    h = apply_norm(cfg, p["norm1"], x)
    cache: Dict[str, Any] = {}
    B, T = x.shape[:2]
    dt = dtype_of(cfg.dtype)
    if fl in ("g", "l"):
        window = cfg.window if fl == "l" else None
        if cfg.mla is not None:
            h2, cache = _mla_prefill(cfg, p["mixer"], h, positions, max_len)
        else:
            q, k, v = attn._project_qkv(cfg, p["mixer"], h, positions)
            pos = positions[..., 0] if positions.ndim == 3 else positions
            o = attn._dispatch_attend(q, k, v, pos, pos, window, True,
                                      cfg.resolved_head_dim ** -0.5,
                                      cfg.attn_softcap)
            h2 = o.reshape(B, T, -1) @ p["mixer"]["wo"].astype(h.dtype)
            S = min(window, max_len) if window else max_len
            if window and T >= S:
                ck = jnp.roll(k[:, T - S:], shift=T % S, axis=1)
                cv = jnp.roll(v[:, T - S:], shift=T % S, axis=1)
            else:
                ck = jnp.zeros((B, S) + k.shape[2:], dt)
                ck = jax.lax.dynamic_update_slice(
                    ck, k.astype(dt), (0, 0, 0, 0))
                cv = jnp.zeros((B, S) + v.shape[2:], dt)
                cv = jax.lax.dynamic_update_slice(
                    cv, v.astype(dt), (0, 0, 0, 0))
            cache = {"k": ck, "v": cv}
        h = h2
    elif fl == "r":
        u = h @ p["mixer"]["w_in"].astype(h.dtype)
        h2 = rec.rglru_train(cfg, p["mixer"], h)
        # recurrent state at T: recompute last hidden via scan tail
        conv_state = u[:, -(rec._CONV_W - 1):, :].astype(dt)
        full = _rglru_hidden(cfg, p["mixer"], h)
        cache = {"h": full[:, -1].astype(jnp.float32), "conv": conv_state}
        h = h2
    elif fl == "m":
        h2, state = _mlstm_prefill(cfg, p["mixer"], h)
        cache = state
        h = h2
    else:
        h2, state = _slstm_prefill(cfg, p["mixer"], h)
        cache = state
        h = h2
    if cfg.post_norm:
        h = apply_norm(cfg, p["norm1_post"], h)
    x = x + h
    if "cross" in p:
        hx = apply_norm(cfg, p["norm_x"], x)
        hx2 = _cross_attend(cfg, p["cross"], hx, enc_out, positions, enc_pos)
        x = x + hx2
        hd = cfg.resolved_head_dim
        S = enc_out.shape[1]
        cache["xk"] = (enc_out @ p["cross"]["wk"].astype(x.dtype)).reshape(
            B, S, cfg.n_kv_heads, hd).astype(dt)
        cache["xv"] = (enc_out @ p["cross"]["wv"].astype(x.dtype)).reshape(
            B, S, cfg.n_kv_heads, hd).astype(dt)
    if "ffn" in p or "moe" in p:
        hh = apply_norm(cfg, p["norm2"], x)
        if "moe" in p:
            hh, _ = moe_mod.apply_moe(cfg, p["moe"], hh)
        else:
            hh = apply_ffn(cfg, p["ffn"], hh)
        if cfg.post_norm:
            hh = apply_norm(cfg, p["norm2_post"], hh)
        x = x + hh
    return x, cache


def _mla_prefill(cfg, p, x, positions, max_len):
    m = cfg.mla
    dt_s = dtype_of(cfg.dtype)
    B, T, _ = x.shape
    out = attn.mla_train(cfg, p, x, positions)
    kv_a = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_pe = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    pos = positions[..., 0] if positions.ndim == 3 else positions
    k_pe = attn.apply_rope(k_pe[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    cc = jnp.zeros((B, max_len, m.kv_lora_rank), dt_s)
    cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(dt_s), (0, 0, 0))
    cp = jnp.zeros((B, max_len, m.qk_rope_head_dim), dt_s)
    cp = jax.lax.dynamic_update_slice(cp, k_pe.astype(dt_s), (0, 0, 0))
    return out, {"c_kv": cc, "k_pe": cp}


def _rglru_hidden(cfg, p, x):
    dt = x.dtype
    u = x @ p["w_in"].astype(dt)
    u = rec._causal_conv(u, p["conv"].astype(dt))
    r_gate = jax.nn.sigmoid((u @ p["w_a"].astype(dt)).astype(jnp.float32))
    i_gate = jax.nn.sigmoid((u @ p["w_x"].astype(dt)).astype(jnp.float32))
    log_a = -rec._LRU_C * jax.nn.softplus(
        p["lambda"].astype(jnp.float32)) * r_gate
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i_gate * u.astype(jnp.float32))
    return rec._lru_scan_assoc(log_a, b)


def _mlstm_prefill(cfg, p, x):
    B, T, d = x.shape
    H = cfg.n_heads
    hd = (cfg.lru_dim or d) // H
    q, k, v, logi, logf = rec._mlstm_qkvif(cfg, p, x)
    init = (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32))
    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, logi, logf))
    carry, hs = jax.lax.scan(rec._mlstm_step, init, xs)
    h = hs.swapaxes(0, 1).reshape(B, T, H * hd).astype(x.dtype)
    z = jax.nn.silu(x @ p["wz"].astype(x.dtype))
    out = (h * z) @ p["wo"].astype(x.dtype)
    return out, {"C": carry[0], "n": carry[1], "m": carry[2]}


def _slstm_prefill(cfg, p, x):
    B, T, d = x.shape
    H = cfg.n_heads
    hd = (cfg.lru_dim or d) // H
    xz, xi, xf, xo = rec._slstm_inputs(cfg, p, x)
    p32 = {k: p[k].astype(jnp.float32) for k in ("rz", "ri", "rf", "ro")}
    z = jnp.zeros((B, H, hd), jnp.float32)
    init = (z, z, jnp.full((B, H, hd), -1e30, jnp.float32), z)
    xs = tuple(a.swapaxes(0, 1) for a in (xz, xi, xf, xo))
    carry, hs = jax.lax.scan(lambda c, i: rec._slstm_step(p32, c, i), init, xs)
    h = hs.swapaxes(0, 1).reshape(B, T, H * hd).astype(x.dtype)
    out = h @ p["wo"].astype(x.dtype)
    return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}


def decode_step(cfg, params, cache, tokens, pos):
    """One decode step.  tokens: (B, 1) i32; pos: scalar i32 position.
    Returns (logits (B, V), new cache)."""
    x = _embed_tokens(cfg, params, tokens)
    segs = plan_segments(layer_kinds(cfg))
    new_caches = {}
    for si, (unit, count) in enumerate(segs):
        stacked = params["segments"][f"seg{si}"]
        seg_cache = cache[f"seg{si}"]

        def body(h, inp, unit=unit):
            p_l, c_l = inp
            new_c = {}
            for ui, kind in enumerate(unit):
                h, nc = apply_block_decode(cfg, kind, p_l[f"u{ui}"], h,
                                           c_l[f"u{ui}"], pos)
                new_c[f"u{ui}"] = nc
            return h, new_c

        x, new_caches[f"seg{si}"] = jax.lax.scan(body, x, (stacked, seg_cache))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_head_logits(cfg, params, x)
    return logits[:, 0], new_caches


def count_params(params) -> int:
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(params))
