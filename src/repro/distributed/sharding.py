"""Per-arch PartitionSpec rules (DP / FSDP / TP / EP / SP).

The mesh has axes (data, model) per pod, plus a leading ``pod`` axis in the
multi-pod configuration.  Data parallelism runs over (pod, data); tensor
parallelism over ``model``; experts (EP) shard their leading expert axis
over ``model``; FSDP additionally shards large parameter matrices over the
data axes (required for deepseek-v3 / qwen1.5-32b / gemma2-27b).

Rules are name-based over the flattened param tree — auditable with
``describe_shardings``.  GSPMD handles non-divisible dims by padding, so
rules do not need divisibility checks.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.mask_pack import ops as mask_ops
from repro.kernels.mask_pack.kernel import BLOCK


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(cfg, mesh: Mesh, name: str, leaf) -> P:
    """PartitionSpec for one parameter leaf (name = '/'-joined path)."""
    dp = data_axes(mesh)
    fs = dp if cfg.fsdp else None  # FSDP shard axis group (or None)
    nd = len(leaf.shape)
    last = name.rsplit("/", 1)[-1]
    has_stack = "segments" in name or "blocks" in name  # leading scan dim

    def spec(*dims):
        """dims for the *logical* (unstacked) shape; prepend None if stacked."""
        if has_stack:
            return P(*((None,) + dims))
        return P(*dims)

    logical_nd = nd - 1 if has_stack else nd

    # --- embeddings / head ---
    if name == "embed":
        return P("model", fs)              # vocab over TP, d over FSDP
    if name == "lm_head":
        return P(fs, "model")

    # --- norms / scalars ---
    if last in ("scale", "bias", "lambda") or logical_nd <= 1:
        return spec(*(None,) * logical_nd)

    # --- MoE experts: EP over the expert axis ---
    if "/moe/" in name or name.endswith("/moe"):
        if last == "router":
            return spec(None, None)
        if "shared" in name:
            if last in ("wi", "wg"):
                return spec(None, fs, "model")
            return spec(None, "model", fs)
        if last in ("wi", "wg"):       # (E, d, f)
            return spec("model", fs, None)
        if last == "wo":               # (E, f, d)
            return spec("model", None, fs)

    # --- attention / mixers ---
    if last in ("wq", "wk", "wv", "wz", "wi", "wf", "wg",
                "wq_b", "wk_b", "wv_b", "w_in", "w_gate"):
        return spec(fs, "model")           # column parallel
    if last in ("wo", "w_out"):
        return spec("model", fs)           # row parallel
    if last in ("wq_a", "wkv_a"):
        return spec(fs, None)              # low-rank down-proj (small out dim)
    if last in ("bq", "bk", "bv"):
        return spec("model")
    if last == "conv":
        return spec(None, "model")
    if last in ("w_a", "w_x"):             # (r, r) LRU gates
        return spec(None, "model")
    if last in ("rz", "ri", "rf", "ro"):   # (H, hd, hd) sLSTM recurrent
        return spec("model", None, None)

    return spec(*(None,) * logical_nd)


def fit_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop axes whose size does not divide the dim (jit in_shardings
    require exact divisibility).  Handles tuple axis entries by keeping the
    longest divisible prefix of the group."""
    dims = list(spec)
    dims = dims + [None] * (len(shape) - len(dims))
    out = []
    for d, n in zip(dims, shape):
        if d is None:
            out.append(None)
            continue
        axes = d if isinstance(d, tuple) else (d,)
        kept = []
        prod = 1
        for a in axes:
            sz = mesh.shape[a]
            if n % (prod * sz) == 0:
                kept.append(a)
                prod *= sz
            else:
                break
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    return P(*out)


def params_shardings(cfg, mesh: Mesh, params_shape) -> Any:
    """Map a params pytree (arrays or ShapeDtypeStructs) to NamedShardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        p = param_spec(cfg, mesh, _path_str(path), leaf)
        out.append(NamedSharding(mesh, fit_spec(mesh, p, leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(cfg, mesh: Mesh, batch_shape, *, seq_shard: bool = False):
    """Batch dim over (pod, data); optional SP shards the seq dim over
    ``model`` (long-context training)."""
    dp = data_axes(mesh)
    sp = "model" if seq_shard else None

    def one(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        if nd == 1:
            spec = P(None)
        elif nd == 2:   # (B, T)
            spec = P(dp, sp)
        else:           # (B, T, d) stub embeddings / (B, T, 3) positions
            spec = P(dp, sp, *(None,) * (nd - 2))
        return NamedSharding(mesh, fit_spec(mesh, spec, leaf.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def cache_shardings(cfg, mesh: Mesh, cache_shape):
    """KV caches: batch over (pod, data), heads/latent dim over model."""
    dp = data_axes(mesh)

    def one(path, leaf):
        name = _path_str(path)
        last = name.rsplit("/", 1)[-1]
        nd = len(leaf.shape)
        # stacked over a leading scan dim inside segments
        stacked = "seg" in name
        pre = (None,) if stacked else ()
        lnd = nd - len(pre)
        if last in ("k", "v", "xk", "xv") and lnd == 4:   # (B,S,K,hd)
            spec = P(*pre, dp, None, "model", None)
        elif last in ("c_kv", "k_pe") and lnd == 3:       # (B,S,R) MLA latent
            spec = P(*pre, dp, None, None)
        elif last == "C" and lnd == 4:                    # (B,H,hd,hd)
            spec = P(*pre, dp, "model", None, None)
        elif lnd >= 2:
            spec = P(*pre, dp, *(None,) * (lnd - 1))
        else:
            spec = P(*pre, *(None,) * lnd)
        return NamedSharding(mesh, fit_spec(mesh, spec, leaf.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


# --------------------------------------------------------------------------
# Scrutinized checkpoint save path: pack per shard *before* any gather.
# --------------------------------------------------------------------------

def _as_flat_mask(mask):
    """Flat view of a criticality mask without forcing a host round-trip:
    resident device masks (a ``DeviceReport``'s) stay on device — the whole
    point of the device scrutiny engine is that saves never re-upload the
    mask — while host numpy masks keep the original behaviour."""
    if isinstance(mask, jax.Array):
        return jnp.ravel(mask)
    return np.asarray(mask).reshape(-1)


def _mask_segment(mask, lo: int, hi: int, data):
    """Slice ``mask[lo:hi]`` for one leading-axis shard, colocated with the
    shard's ``data`` when the mask is a device array (a sharded/resident
    mask's slice may live elsewhere; jitted pack rejects mixed devices)."""
    seg = mask[lo:hi]
    if isinstance(mask, jax.Array):
        seg = jax.device_put(seg, next(iter(data.devices())))
    return seg


def scrutiny_words_shardings(state, shardings) -> Dict[str, Any]:
    """Per-leaf shardings for the scrutiny engine's bit-packed mask words.

    For every leaf whose sharding tiles only the leading axis into
    byte-aligned flat segments (the DP/FSDP parameter layouts that
    ``pack_sharded_payload`` packs per shard), the flat word array
    ``(ceil(n/8),)`` can carry the same leading-axis spec — per-shard mask
    words then land on the device whose shard they describe.  Leaves with
    any other layout map to ``None`` (words stay wherever the sweep puts
    them).  Feed the result to ``scrutinize(..., mask_shardings=...)``.
    """
    flat_t = jax.tree_util.tree_flatten_with_path(state)[0]
    # None entries mean "no sharding for this leaf" and must stay leaves
    # (bare tree_leaves would silently drop them and misalign the zip)
    flat_s = jax.tree_util.tree_leaves(
        shardings,
        is_leaf=lambda x: x is None or isinstance(x, NamedSharding))
    out: Dict[str, Any] = {}
    for (path, leaf), sh in zip(flat_t, flat_s):
        name = _path_str(path)
        out[name] = None
        if not isinstance(sh, NamedSharding) or not len(leaf.shape):
            continue
        spec = tuple(sh.spec) + (None,) * (len(leaf.shape) - len(sh.spec))
        if spec[0] is None or any(d is not None for d in spec[1:]):
            continue
        axes = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
        nshards = int(np.prod([sh.mesh.shape[a] for a in axes]))
        row = int(np.prod(leaf.shape[1:])) if len(leaf.shape) > 1 else 1
        if nshards <= 1 or leaf.shape[0] % nshards:
            continue
        if (leaf.shape[0] // nshards * row) % 8:
            continue  # shard boundary splits a word byte: keep replicated
        out[name] = NamedSharding(sh.mesh, P(spec[0]))
    return out

def _leading_axis_shards(leaf) -> Optional[List[Tuple[int, int, Any]]]:
    """If ``leaf``'s addressable shards tile only the leading axis (all other
    dims full), return [(start, stop, shard_data)] sorted and exactly covering
    axis 0; else None.  Replicated copies are deduplicated."""
    shards = getattr(leaf, "addressable_shards", None)
    if not shards or leaf.ndim == 0:
        return None
    uniq: Dict[int, Any] = {}
    stops: Dict[int, int] = {}
    for sh in shards:
        idx = sh.index
        if len(idx) != leaf.ndim:
            return None
        for d, sl in enumerate(idx[1:], start=1):
            if sl.step not in (None, 1):
                return None
            if sl.start not in (None, 0):
                return None
            if sl.stop is not None and sl.stop != leaf.shape[d]:
                return None
        sl0 = idx[0]
        if sl0.step not in (None, 1):
            return None
        s = sl0.start or 0
        e = leaf.shape[0] if sl0.stop is None else sl0.stop
        uniq.setdefault(s, sh.data)
        stops[s] = e
    starts = sorted(uniq)
    if starts[0] != 0 or stops[starts[-1]] != leaf.shape[0]:
        return None
    for a, b in zip(starts, starts[1:]):
        if stops[a] != b:
            return None
    return [(s, stops[s], uniq[s]) for s in starts]


def leaf_segments(leaf) -> Optional[List[Tuple[int, int, Any]]]:
    """Public wrapper for the pipelined save engine: the leading-axis
    [(start, stop, shard_data)] tiling of a multi-shard addressable leaf,
    or ``None`` for single-device / unsupported layouts (the caller then
    treats the leaf as one flat segment)."""
    if getattr(leaf, "is_fully_addressable", True) and \
            len(getattr(leaf, "addressable_shards", ()) or ()) > 1:
        return _leading_axis_shards(leaf)
    return None


def pack_sharded_payload(leaf, mask: np.ndarray, *, block: int = BLOCK,
                         use_kernel: Optional[bool] = None,
                         interpret: bool = False):
    """Pack a (possibly sharded) device array's critical elements, moving
    only packed bytes device→host.

    When the leaf is sharded along its leading axis (DP/FSDP parameter
    layouts), each shard is compacted **on its own device** and only its
    critical prefix crosses D2H — no cross-device gather of the full leaf
    ever happens.  Any other layout falls back to a global device-side pack
    (XLA handles the collective; the host still only receives packed bytes).

    Returns ``(payload, counts, d2h_bytes)`` with ``payload`` in global flat
    (C) order — identical bytes to the host path.

    ``mask`` may be a host bool array or a resident device mask (from a
    ``DeviceReport``) — the latter never round-trips through the host.
    """
    mask = _as_flat_mask(mask)
    segs = None
    if getattr(leaf, "is_fully_addressable", True) and \
            len(getattr(leaf, "addressable_shards", ()) or ()) > 1:
        segs = _leading_axis_shards(leaf)
    if segs is None:
        return mask_ops.pack_critical(jnp.ravel(leaf), mask, block=block,
                                      use_kernel=use_kernel,
                                      interpret=interpret)
    row = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
    payloads, counts, moved = [], [], 0
    for s, e, data in segs:
        p, c, m = mask_ops.pack_critical(
            jnp.ravel(data), _mask_segment(mask, s * row, e * row, data),
            block=block, use_kernel=use_kernel, interpret=interpret)
        payloads.append(p)
        counts.append(c)
        moved += m
    return (np.concatenate(payloads), np.concatenate(counts), moved)


def _pack_payload_device(flat, mask, *, block: int = BLOCK,
                         use_kernel: Optional[bool] = None,
                         interpret: bool = False):
    """Pack one flat leaf's critical elements, keeping the payload on
    device.  Returns (payload_dev, counts_h, d2h_bytes) — only the per-tile
    counts cross D2H here; the payload moves (or is delta-encoded) later."""
    packed, counts = mask_ops.pack(flat, jnp.asarray(mask), block=block,
                                   use_kernel=use_kernel, interpret=interpret)
    counts_h = np.asarray(counts)                  # D2H: 4 B / tile
    total = int(counts_h.sum())
    if total:
        payload = mask_ops.gather_payload(packed, counts, total=total)
    else:
        payload = packed.reshape(-1)[:0]
    return payload, counts_h, counts_h.nbytes


def pack_sharded_payload_device(leaf, mask: np.ndarray, *, block: int = BLOCK,
                                use_kernel: Optional[bool] = None,
                                interpret: bool = False):
    """Device-resident variant of :func:`pack_sharded_payload` for the
    differential save path: each leading-axis shard is compacted on its own
    device, then the (already critical-fraction-sized) payloads are
    concatenated into one device array that stays resident as the delta
    base — only the per-tile counts cross D2H.

    Returns ``(payload_dev, counts_h, d2h_bytes)``.  Note the concatenation
    gathers the *packed* payloads onto one device; cross-device traffic is
    ∝ the critical fraction, never the full leaf.  Like
    :func:`pack_sharded_payload`, a resident device mask is consumed
    without any host round-trip.
    """
    mask = _as_flat_mask(mask)
    segs = None
    if getattr(leaf, "is_fully_addressable", True) and \
            len(getattr(leaf, "addressable_shards", ()) or ()) > 1:
        segs = _leading_axis_shards(leaf)
    if segs is None:
        return _pack_payload_device(jnp.ravel(leaf), mask, block=block,
                                    use_kernel=use_kernel,
                                    interpret=interpret)
    row = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
    payloads, counts, moved = [], [], 0
    for s, e, data in segs:
        p, c, m = _pack_payload_device(
            jnp.ravel(data), _mask_segment(mask, s * row, e * row, data),
            block=block, use_kernel=use_kernel, interpret=interpret)
        payloads.append(p)
        counts.append(c)
        moved += m
    # co-locate the packed (critical-fraction-sized) payloads before the
    # concat — committed arrays on different devices refuse to mix lazily
    home = payloads[0].devices()
    payloads = [p if p.devices() == home else jax.device_put(p, list(home)[0])
                for p in payloads]
    return jnp.concatenate(payloads), np.concatenate(counts), moved


# --------------------------------------------------------------------------
# Scrutinized restore path: scatter per shard *after* a payload-only H2D.
# --------------------------------------------------------------------------

def _leading_axis_segments(sharding, shape
                           ) -> Optional[List[Tuple[int, int, Any]]]:
    """Per-device leading-axis segments of a target ``sharding`` over a
    global ``shape``: [(start, stop, device)], one entry per addressable
    device (replicas repeat their segment); None if the layout slices any
    non-leading dim."""
    if not shape or not hasattr(sharding, "addressable_devices_indices_map"):
        return None
    try:
        idx_map = sharding.addressable_devices_indices_map(tuple(shape))
    except (TypeError, ValueError):
        return None
    out = []
    for dev, idx in idx_map.items():
        if idx is None or len(idx) != len(shape):
            return None
        for d, sl in enumerate(idx[1:], start=1):
            if sl.step not in (None, 1) or sl.start not in (None, 0):
                return None
            if sl.stop is not None and sl.stop != shape[d]:
                return None
        sl0 = idx[0]
        if sl0.step not in (None, 1):
            return None
        s = sl0.start or 0
        e = shape[0] if sl0.stop is None else sl0.stop
        out.append((s, e, dev))
    return out


def leading_axis_device_segments(sharding, shape
                                 ) -> Optional[List[Tuple[int, int, Any]]]:
    """Public wrapper over the leading-axis layout parser for consumers
    outside the scatter path (the multi-host coordinator derives both
    save-time ownership and restore-time target ranges from it):
    per-device ``[(row_start, row_stop, device)]`` of ``sharding`` over a
    global ``shape``, or None when the layout slices a non-leading dim."""
    return _leading_axis_segments(sharding, shape)


def scatter_sharded_payload(payload: np.ndarray, mask: np.ndarray,
                            shape, dtype, sharding=None, *, fill=0,
                            block: int = BLOCK,
                            use_kernel: Optional[bool] = None,
                            interpret: bool = False):
    """Restore inverse of :func:`pack_sharded_payload`: move only the
    critical ``payload`` (plus the bit-packed mask) H2D and scatter it into
    a fill-initialized device buffer via ``kernels/mask_pack``.

    When ``sharding`` tiles only the leading axis, each device receives and
    expands just its own segment's slice of the payload — restore traffic
    per device scales with its local critical fraction; the global array is
    assembled from the single-device pieces without any host round-trip.

    Returns ``(device_array, h2d_bytes)``.
    """
    shape = tuple(shape)
    n = int(np.prod(shape)) if shape else 1
    mask = np.asarray(mask, bool).reshape(-1)
    payload = np.asarray(payload).reshape(-1)
    opts = dict(block=block, use_kernel=use_kernel, interpret=interpret)

    def expand(pay_h, msk_h, local_n, device=None):
        bits = np.packbits(msk_h)
        put = (lambda x: jax.device_put(x, device)) if device is not None \
            else jnp.asarray
        m_dev = mask_ops.expand_mask_bits(put(bits), n=local_n)
        out = mask_ops.mask_scatter(put(pay_h), m_dev, n=local_n,
                                    fill=fill, **opts)
        return out, pay_h.nbytes + bits.nbytes

    segs = _leading_axis_segments(sharding, shape) if sharding is not None \
        else None
    if segs is None:
        out, h2d = expand(payload, mask, n)
        out = out.reshape(shape)
        if sharding is not None:
            out = jax.device_put(out, sharding)
        return out, h2d

    row = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    cum = np.concatenate([[0], np.cumsum(mask)])
    pieces, h2d = [], 0
    for s, e, dev in segs:
        lo, hi = cum[s * row], cum[e * row]
        piece, moved = expand(payload[lo:hi], mask[s * row:e * row],
                              (e - s) * row, device=dev)
        pieces.append(piece.reshape((e - s,) + shape[1:]))
        h2d += moved
    out = jax.make_array_from_single_device_arrays(shape, sharding, pieces)
    return out, h2d


def describe_shardings(cfg, mesh: Mesh, tree, shardings, limit=40) -> str:
    flat_t = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat_s = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    lines = []
    for (path, leaf), sh in list(zip(flat_t, flat_s))[:limit]:
        lines.append(f"{_path_str(path):<60} {str(leaf.shape):<24} {sh.spec}")
    return "\n".join(lines)
