"""Optional GPipe-style pipeline parallelism over homogeneous block stacks.

Production default for this system is 2-axis DP×TP (+pod DP); PP is
provided for archs with uniform blocks when the model axis is insufficient.
The schedule is the classic stage-loop: microbatches stream through
``n_stages`` shard_map stages with collective_permute between neighbours;
bubble fraction = (S-1)/(M+S-1).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe_apply(mesh: Mesh, axis: str, block_fn: Callable, stage_params,
                x, n_microbatch: int):
    """x: (M*mb, T, d) microbatched activations; stage_params stacked over
    the pipeline axis (one slice per stage).  block_fn(params, x) -> x.

    Runs inside shard_map over ``axis``: each device holds one stage's
    params; activations rotate via ppermute.  Returns final activations in
    original microbatch order."""
    S = mesh.shape[axis]

    def staged(params_local, x_local):
        # params_local: this stage's params; x_local: (M/S?...) — we keep
        # the full microbatch stream on every stage and mask by schedule.
        idx = jax.lax.axis_index(axis)
        M = n_microbatch

        def tick(carry, t):
            acts = carry  # (mb, T, d) activation currently at this stage
            # stage s processes microbatch (t - s) when 0 <= t - s < M
            active = (t - idx >= 0) & (t - idx < M)
            mb_idx = jnp.clip(t - idx, 0, M - 1)
            cur = jax.lax.cond(
                idx == 0,
                lambda: jax.lax.dynamic_index_in_dim(x_local, mb_idx, 0,
                                                     keepdims=False),
                lambda: acts)
            out = block_fn(params_local, cur)
            out = jnp.where(active, out, cur)
            # pass downstream
            nxt = jax.lax.ppermute(out, axis,
                                   [(i, (i + 1) % S) for i in range(S)])
            return nxt, jnp.where((t - idx == jnp.asarray(S - 1)) &
                                  active, out, jnp.zeros_like(out))

        T = M + S - 1
        init = jnp.zeros_like(x_local[0])
        _, outs = jax.lax.scan(tick, init, jnp.arange(T))
        # collect the slices emitted by the last stage
        return outs

    return shard_map(
        staged, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(None),
        check_rep=False,
    )(stage_params, x)
