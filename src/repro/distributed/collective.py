"""Multi-host coordination primitives for coordinated checkpointing.

Checkpointing a sharded job is a *collective* operation: every process
writes only the shards it owns, then all of them must agree the step is
complete before it becomes visible (checkpoint/coordinator.py implements
the two-phase commit on top of these primitives).  This module owns the
two things the coordinator needs from the outside world:

- **Process identity** (``ProcessContext``): who am I, how many of us are
  there, who is the leader.  Resolved from ``jax.process_index()`` /
  ``jax.process_count()`` in a real multi-controller job, or from the
  ``REPRO_PROCESS_INDEX`` / ``REPRO_PROCESS_COUNT`` environment variables
  when multi-host is *simulated* by independent single-process jax
  runtimes (the subprocess/thread test harnesses, single-node launchers).

- **Barriers** (``Collective.barrier``): rendezvous points between the
  commit phases.  Two interchangeable backends:

  * ``JaxCollective`` — ``jax.experimental.multihost_utils.
    sync_global_devices`` on a real multi-process jax runtime (the
    barrier rides the ICI/DCN collective fabric; no timeout — the
    runtime owns failure detection);
  * ``FileCollective`` — filesystem rendezvous over a shared directory:
    each participant touches ``b_<name>.p<i>`` and spins until all
    ``count`` files exist, with a timeout so the death of one host turns
    into a ``TimeoutError`` on the survivors instead of a hang.  This is
    the fallback for tests and for launchers whose jax runtimes are
    independent (each host sees only its own devices but all hosts share
    a filesystem).

Barrier names must be unique per rendezvous (the coordinator derives them
from a per-manager monotonically increasing sequence number, which stays
consistent across hosts because every host calls ``save`` in the same
order — the usual SPMD discipline).  Stale barrier files from a crashed
previous run are cleared by the leader at construction; a live host whose
file was swept by that cleanup simply re-touches it from its wait loop.
"""

from __future__ import annotations

import dataclasses
import os
import random
import re
import time
from typing import Any, List, Optional, Sequence, Tuple

_ENV_INDEX = "REPRO_PROCESS_INDEX"
_ENV_COUNT = "REPRO_PROCESS_COUNT"
_ENV_COORD = "REPRO_COORD_DIR"

_NAME_RE = re.compile(r"[^A-Za-z0-9._-]")


@dataclasses.dataclass(frozen=True)
class ProcessContext:
    """Identity of this process within the coordinated job."""
    index: int
    count: int

    def __post_init__(self):
        if not 0 <= self.index < self.count:
            raise ValueError(
                f"process index {self.index} outside [0, {self.count})")

    @property
    def is_leader(self) -> bool:
        return self.index == 0


def current_context() -> ProcessContext:
    """Resolve this process's identity.

    ``REPRO_PROCESS_INDEX``/``REPRO_PROCESS_COUNT`` (the simulated
    multi-host harness) win over the jax runtime's notion — a simulated
    host is a *single-process* jax runtime, so ``jax.process_count()``
    would report 1 for every participant.
    """
    if _ENV_COUNT in os.environ:
        return ProcessContext(index=int(os.environ.get(_ENV_INDEX, "0")),
                              count=int(os.environ[_ENV_COUNT]))
    try:
        import jax
        return ProcessContext(index=jax.process_index(),
                              count=jax.process_count())
    except Exception:   # noqa: BLE001 - jax not initialized / unavailable
        return ProcessContext(index=0, count=1)


class BarrierTimeout(TimeoutError):
    """A barrier's deadline passed with participants still missing.

    Failure detection for the degradation layer: ``missing`` carries the
    process indices that never arrived, so the coordinator can compute
    the surviving quorum and recover the dead hosts' segments from their
    partners' L2 copies instead of aborting the save.
    """

    def __init__(self, name: str, missing: Sequence[int], expected: int,
                 waited_s: float, arrivals: Optional[dict] = None):
        self.barrier_name = name
        self.missing = sorted(int(m) for m in missing)
        self.expected = int(expected)
        self.waited_s = float(waited_s)
        # per-host first-seen arrival delay (seconds after this process
        # entered the barrier); absent hosts have no entry — the gap data
        # feeding the heartbeat-gap gauges even on the failure path
        self.arrivals = dict(arrivals or {})
        hosts = ", ".join(f"host {m}" for m in self.missing)
        super().__init__(
            f"barrier {name!r}: processes {self.missing} of "
            f"{self.expected} never arrived within {self.waited_s:.1f}s "
            f"({hosts} presumed dead)")


class Collective:
    """Barrier provider bound to a ``ProcessContext``.

    ``participants``: optional explicit quorum (sorted process indices)
    for backends that support membership-aware rendezvous — after a
    detected host death the coordinator re-runs its commit barriers over
    the surviving quorum only.  Backends without liveness control ignore
    it (the full membership is then implied).
    """

    def __init__(self, ctx: ProcessContext):
        self.ctx = ctx
        # optional repro.obs.Observability bundle; backends that poll
        # record barrier waits / per-host arrival gaps through it
        self.obs: Optional[Any] = None

    def barrier(self, name: str, timeout: Optional[float] = None,
                participants: Optional[Sequence[int]] = None,
                heartbeat: Optional[Any] = None) -> None:
        """Rendezvous ``name`` with the other participants.  ``heartbeat``
        (a zero-arg callable) is invoked on every poll iteration by
        backends that wait by polling — a barrier running on a writer
        thread uses it to keep ``.alive`` liveness tokens fresh."""
        raise NotImplementedError

    def cleanup(self, before_seq: int) -> None:
        """Drop this process's rendezvous residue for barriers whose
        sequence number is ``< before_seq`` (no-op unless the backend
        leaves files behind)."""

    def close(self) -> None:
        pass


class NullCollective(Collective):
    """Single-process job: every barrier is trivially satisfied."""

    def __init__(self, ctx: Optional[ProcessContext] = None):
        super().__init__(ctx or ProcessContext(0, 1))
        if self.ctx.count != 1:
            raise ValueError("NullCollective requires process_count == 1")

    def barrier(self, name: str, timeout: Optional[float] = None,
                participants: Optional[Sequence[int]] = None,
                heartbeat: Optional[Any] = None) -> None:
        return None


class JaxCollective(Collective):
    """Real multi-controller jax runtime: barrier over the device fabric.

    ``timeout`` is ignored — the distributed runtime owns liveness (a dead
    host fails the whole job well before a checkpoint barrier would)."""

    def __init__(self, ctx: Optional[ProcessContext] = None):
        import jax
        super().__init__(ctx or ProcessContext(jax.process_index(),
                                               jax.process_count()))

    def barrier(self, name: str, timeout: Optional[float] = None,
                participants: Optional[Sequence[int]] = None,
                heartbeat: Optional[Any] = None) -> None:
        # participants is ignored: the fabric barrier has no membership
        # control (a dead host fails the whole job at the runtime layer,
        # so a degraded quorum never reaches this backend)
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(_NAME_RE.sub("_", name))


class FileCollective(Collective):
    """Filesystem rendezvous over a shared directory.

    Each participant touches ``b_<name>.p<index>`` and polls until all
    participant files for that name exist (all ``count`` processes, or
    the explicit ``participants`` quorum).  The poll loop re-touches its
    own file if it goes missing (so the constructor's stale-file cleanup
    can never wedge a live barrier), backs off exponentially with jitter
    from ``poll_s`` up to ``max_poll_s`` (resetting whenever a new
    participant arrives, so a nearly-complete barrier stays responsive
    while a stalled one stops hammering the shared filesystem), and
    raises ``BarrierTimeout`` carrying the indices of the participants
    that never arrived — a dead host fails the collective with an
    attributable error instead of hanging it.
    """

    def __init__(self, directory: str, ctx: Optional[ProcessContext] = None,
                 poll_s: float = 0.01, timeout_s: float = 120.0,
                 max_poll_s: float = 0.25):
        super().__init__(ctx or current_context())
        self.directory = directory
        self.poll_s = float(poll_s)
        self.timeout_s = float(timeout_s)
        self.max_poll_s = max(float(max_poll_s), self.poll_s)
        os.makedirs(directory, exist_ok=True)
        # Leftovers from a crashed previous run would satisfy this run's
        # barriers instantly (sequence numbers restart every run), so the
        # leader sweeps *every* barrier file at construction — even fresh
        # ones a fast supervisor restart carried over.  A live host whose
        # in-flight file got swept re-touches it from its wait loop, so
        # the only casualty of an over-eager sweep is a retry, never a
        # barrier that passes with a dead run's files.  (One coordination
        # dir therefore serves one job at a time.)
        if self.ctx.is_leader:
            for f in os.listdir(directory):
                if not f.startswith("b_"):
                    continue
                try:
                    os.unlink(os.path.join(directory, f))
                except OSError:
                    continue

    def _path(self, name: str, index: int) -> str:
        return os.path.join(self.directory,
                            f"b_{_NAME_RE.sub('_', name)}.p{index}")

    def barrier(self, name: str, timeout: Optional[float] = None,
                participants: Optional[Sequence[int]] = None,
                heartbeat: Optional[Any] = None) -> None:
        procs = (sorted(set(int(p) for p in participants))
                 if participants is not None else list(range(self.ctx.count)))
        if self.ctx.index not in procs:
            return              # not part of this quorum's rendezvous
        mine = self._path(name, self.ctx.index)
        with open(mine, "w") as f:
            f.write(str(self.ctx.index))
        wait_s = self.timeout_s if timeout is None else float(timeout)
        t0 = time.monotonic()
        deadline = t0 + wait_s
        poll = self.poll_s
        last_missing = len(procs)
        arrivals = {self.ctx.index: 0.0}    # host -> first-seen delay (s)
        while True:
            if heartbeat is not None:
                heartbeat()
            now = time.monotonic()
            missing = [j for j in procs
                       if not os.path.exists(self._path(name, j))]
            for j in procs:
                if j not in missing:
                    arrivals.setdefault(j, now - t0)
            if not missing:
                self._record_barrier(time.monotonic() - t0, arrivals,
                                     timed_out=False)
                return
            if self.ctx.index in missing:   # swept by a leader cleanup
                with open(mine, "w") as f:
                    f.write(str(self.ctx.index))
            if time.monotonic() > deadline:
                self._record_barrier(time.monotonic() - t0, arrivals,
                                     timed_out=True)
                raise BarrierTimeout(name, missing, len(procs), wait_s,
                                     arrivals=arrivals)
            if len(missing) < last_missing:     # progress: stay responsive
                poll = self.poll_s
            last_missing = len(missing)
            # bounded exponential backoff; jitter desynchronizes the
            # herd of pollers hitting the shared directory
            time.sleep(poll * (0.75 + 0.5 * random.random()))
            poll = min(poll * 2.0, self.max_poll_s)

    def _record_barrier(self, waited_s: float, arrivals: dict,
                        timed_out: bool) -> None:
        """Feed the barrier wait + per-host arrival gaps to the attached
        telemetry registry (success *and* timeout paths — slow-peer gap
        maxima are most interesting right before a death)."""
        obs = self.obs
        if obs is None or not obs.enabled:
            return
        reg = obs.registry
        reg.histogram("barrier.wait_s").observe(waited_s)
        for j, gap in sorted(arrivals.items()):
            reg.gauge(f"barrier.arrival_gap_s.host{j}").set(gap)
        if timed_out:
            reg.counter("barrier.timeouts").inc()

    def cleanup(self, before_seq: int) -> None:
        """Unlink this process's *own* files for barriers tagged
        ``q<seq>.`` with ``seq < before_seq``.  Safe because barriers are
        strictly ordered per process: reaching sequence N implies every
        participant passed N-1 and earlier."""
        suffix = f".p{self.ctx.index}"
        for f in os.listdir(self.directory):
            if not (f.startswith("b_q") and f.endswith(suffix)):
                continue
            try:
                seq = int(f[len("b_q"):].split(".", 1)[0])
            except ValueError:
                continue
            if seq < before_seq:
                try:
                    os.unlink(os.path.join(self.directory, f))
                except OSError:
                    pass


def get_collective(backend: str = "auto",
                   coord_dir: Optional[str] = None,
                   ctx: Optional[ProcessContext] = None,
                   **kwargs) -> Collective:
    """Pick the coordination backend.

    ``auto``: a simulated multi-host context (``REPRO_PROCESS_COUNT`` env,
    or an explicit ``ctx`` with ``count > 1``) uses the filesystem
    rendezvous (``coord_dir`` or ``REPRO_COORD_DIR`` must name the shared
    directory); a real multi-process jax runtime uses the device-fabric
    barrier; anything else is the single-process no-op.
    """
    if backend not in ("auto", "jax", "file", "null"):
        raise ValueError(f"unknown collective backend {backend!r}")
    ctx = ctx or current_context()
    coord_dir = coord_dir or os.environ.get(_ENV_COORD)
    if backend == "null" or (backend == "auto" and ctx.count == 1):
        return NullCollective(ctx if ctx.count == 1 else None)
    if backend == "file" or (backend == "auto" and _ENV_COUNT in os.environ):
        # The simulation env means every participant is an *independent*
        # single-process jax runtime: the fabric barrier would be a no-op
        # there and the commit protocol would run unsynchronized, so a
        # missing rendezvous dir is a hard error rather than a fallback.
        if coord_dir is None:
            raise ValueError("file collective needs coord_dir "
                             f"(or ${_ENV_COORD})")
        return FileCollective(coord_dir, ctx=ctx, **kwargs)
    return JaxCollective(ctx)


# --------------------------------------------------------------------------
# Shard ownership: which flat element ranges of a leaf this process writes
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HostPinned:
    """Ownership sentinel: the whole leaf lives on exactly one process.

    Serving state (a decode session's KV cache, position, token tail) is
    *host-local* — it exists only on the host running the session, so the
    near-equal leading-axis split that balances replicated training leaves
    would make other hosts write rows they do not have.  Passing
    ``HostPinned(owner)`` as a leaf's sharding pins every byte of it to
    ``owner``: that process writes the whole leaf, every other process
    writes nothing (and skips the leaf entirely in its snapshot).

    The ``spec`` attribute makes the sentinel duck-type as a sharding for
    the tree-flattening layers (leaves are detected via
    ``hasattr(x, "spec")``), so a shardings tree may freely mix
    ``NamedSharding``, ``None``, and ``HostPinned`` per leaf.
    """
    owner: int
    spec: Any = None

    def __post_init__(self):
        if self.owner < 0:
            raise ValueError(f"HostPinned owner must be >= 0: {self.owner}")


def process_segments(shape: Tuple[int, ...], count: int,
                     sharding=None) -> List[Tuple[int, int, int]]:
    """Partition a leaf's leading axis into per-process owned segments.

    Returns ``[(row_start, row_stop, owner_process)]`` covering
    ``[0, shape[0])`` exactly, sorted.  Ownership is *deterministic* — every
    process computes the same table, so the union of all hosts' writes
    covers every element exactly once:

    - When ``sharding`` (a ``NamedSharding``) tiles the leading axis over a
      mesh whose devices span multiple jax processes, each device's segment
      is owned by the lowest process index holding a replica of it — the
      natural "I already have these bytes locally" assignment.
    - Otherwise (simulated multi-host, replicated leaves, or layouts that
      slice non-leading dims) the leading axis is split into ``count``
      near-equal contiguous blocks.  Scalars and leaves with fewer rows
      than processes collapse to leader ownership of the whole leaf.
    """
    if count < 1:
        raise ValueError("process count must be >= 1")
    rows = int(shape[0]) if shape else 0
    if isinstance(sharding, HostPinned):
        # whole leaf (rows, scalars, empties alike) belongs to one process;
        # modulo keeps the table well-defined if the job shrank elastically
        return [(0, rows, sharding.owner % count)]
    if not shape or rows == 0:
        return [(0, rows, 0)] if shape else [(0, 0, 0)]
    seg = _device_process_segments(shape, sharding)
    if seg is not None:
        return seg
    if rows < count or count == 1:
        return [(0, rows, 0)]
    base, rem = divmod(rows, count)
    out = []
    start = 0
    for p in range(count):
        stop = start + base + (1 if p < rem else 0)
        out.append((start, stop, p))
        start = stop
    return out


def _device_process_segments(shape, sharding):
    """Leading-axis segments mapped to owning processes via the sharding's
    device placement; None when the layout is unsupported or the mesh is
    single-process (the uniform split is then authoritative)."""
    if sharding is None or not hasattr(sharding, "devices_indices_map"):
        return None
    try:
        idx_map = sharding.devices_indices_map(tuple(shape))
    except (TypeError, ValueError):
        return None
    owners = {}
    stops = {}
    procs = set()
    for dev, idx in idx_map.items():
        if idx is None or len(idx) != len(shape):
            return None
        for d, sl in enumerate(idx[1:], start=1):
            if sl.step not in (None, 1) or sl.start not in (None, 0):
                return None
            if sl.stop is not None and sl.stop != shape[d]:
                return None
        sl0 = idx[0]
        if sl0.step not in (None, 1):
            return None
        s = sl0.start or 0
        e = shape[0] if sl0.stop is None else sl0.stop
        proc = getattr(dev, "process_index", 0)
        procs.add(proc)
        if s not in owners or proc < owners[s]:
            owners[s] = proc
            stops[s] = e
    if len(procs) <= 1:
        return None                     # single-process mesh: uniform split
    starts = sorted(owners)
    if not starts or starts[0] != 0 or stops[starts[-1]] != shape[0]:
        return None
    for a, b in zip(starts, starts[1:]):
        if stops[a] != b:
            return None
    return [(s, stops[s], owners[s]) for s in starts]


def owned_ranges(shape: Tuple[int, ...], ctx: ProcessContext,
                 sharding=None) -> List[Tuple[int, int]]:
    """Flat element ranges of a leaf this process owns: each owned
    leading-axis segment ``[lo, hi)`` spans flat ``[lo*row, hi*row)`` where
    ``row`` is the product of the non-leading dims."""
    import numpy as np
    row = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    n = int(np.prod(shape)) if shape else 1
    if isinstance(sharding, HostPinned):
        # must run before the scalar branch: a pinned scalar (a session's
        # decode position) belongs to its owner, not to the leader
        return [(0, n)] if ctx.index == sharding.owner % ctx.count else []
    if not shape:
        return [(0, 1)] if ctx.index == 0 else []
    out = []
    for lo, hi, owner in process_segments(shape, ctx.count, sharding):
        if owner == ctx.index and hi > lo:
            out.append((lo * row, hi * row))
    if not out and n == 0 and ctx.index == 0:
        out.append((0, 0))
    return out
