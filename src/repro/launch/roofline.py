"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips × 197 TFLOP/s)
    memory     = HLO_bytes / (chips × 819 GB/s)
    collective = collective_bytes / (chips × 50 GB/s/link)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute operand sizes).  MODEL_FLOPS = 6·N·D
(6·N_active·D for MoE) bounds how much of the compiled compute is useful.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*\(?((?:pred|[suf]\d+|bf16|c64|c128)\[[\d,]*\][^)]*?|\([^)]*\))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.search(
            r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\b", s)
        if not m or "=" not in s:
            continue
        if m.group(2) == "-done":
            continue  # counted at -start
        lhs = s.split("=", 1)[0]
        b = _shape_bytes(lhs)
        kind = m.group(1)
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: Dict[str, int]
    model_flops: float
    bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return sum(self.coll_bytes.values()) / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline this step achieves, assuming the
        dominant term sets wall-clock: t_model_compute / max(all terms)."""
        t_model = self.model_flops / (self.chips * PEAK_FLOPS)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / t_bound if t_bound else 0.0

    def row(self) -> str:
        cb = sum(self.coll_bytes.values())
        return (f"| {self.arch} | {self.shape} | {self.mesh} "
                f"| {self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} "
                f"| {self.t_collective*1e3:.2f} | {self.dominant} "
                f"| {self.useful_fraction*100:.0f}% "
                f"| {self.roofline_fraction*100:.1f}% |")


def model_flops(cfg, cell) -> float:
    """6·N_active·D (+ attention QKᵀ/PV term) per step.

    train: fwd+bwd (3× fwd); prefill: fwd; decode: one token per sequence.
    The attention term uses the causal-effective context (T/2, or the
    window for local layers) — without it, small-d archs at long T report
    misleadingly low useful fractions."""
    n_active = _active_params(cfg)
    B, T = cell.global_batch, cell.seq_len
    hd = cfg.resolved_head_dim
    attn_fwd = 0.0
    for l in range(cfg.n_layers):
        fl = cfg.pattern_at(l)
        if fl == "g":
            ctx = T / 2
        elif fl == "l":
            ctx = min(cfg.window or T, T)
        else:
            continue
        # QKᵀ + PV: 2 matmuls × 2 flops/MAC over (T × ctx × H × hd)
        attn_fwd += 4.0 * B * T * ctx * cfg.n_heads * hd
    if cfg.enc_dec:
        attn_fwd += 4.0 * B * T * cfg.encoder_len * cfg.n_heads * hd

    if cell.kind == "train":
        return (6.0 * n_active * B * T) + 3.0 * attn_fwd
    if cell.kind == "prefill":
        return (2.0 * n_active * B * T) + attn_fwd
    # decode: one new token attends to the whole context
    dec_attn = 0.0
    for l in range(cfg.n_layers):
        fl = cfg.pattern_at(l)
        if fl == "g":
            dec_attn += 4.0 * B * T * cfg.n_heads * hd
        elif fl == "l":
            dec_attn += 4.0 * B * min(cfg.window or T, T) * cfg.n_heads * hd
    return 2.0 * n_active * B + dec_attn


def _active_params(cfg) -> float:
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.resolved_head_dim
    total = V * d * (1 if cfg.tie_embeddings else 2)
    for l in range(L):
        fl = cfg.pattern_at(l)
        if fl in ("g", "l"):
            if cfg.mla is not None:
                m = cfg.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                total += (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
                          + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                          + m.kv_lora_rank * cfg.n_heads *
                          (m.qk_nope_head_dim + m.v_head_dim)
                          + cfg.n_heads * m.v_head_dim * d)
            else:
                total += d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                    + cfg.n_heads * hd * d
        else:
            r = cfg.lru_dim or d
            total += 4 * d * r  # in/gate/out + gates (approx.)
        if cfg.moe_at(l):
            m = cfg.moe
            total += 3 * (m.top_k + m.num_shared) * d * m.d_expert \
                + d * m.num_experts
        elif cfg.d_ff:
            mult = 3 if cfg.ffn in ("swiglu", "geglu") else 2
            total += mult * d * cfg.d_ff
    if cfg.enc_dec:
        total += cfg.n_encoder_layers * (4 * d * hd * cfg.n_heads // max(
            1, cfg.n_heads) * cfg.n_heads // max(1, cfg.n_heads)
            + 2 * d * cfg.d_ff)
        total += cfg.n_layers * 2 * d * hd * (cfg.n_heads + cfg.n_kv_heads)
    return float(total)


TABLE_HEADER = (
    "| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms "
    "| dominant | useful | roofline |\n"
    "|---|---|---|---|---|---|---|---|---|")
