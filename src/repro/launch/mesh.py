"""Production mesh: 16×16 per pod (TPU v5e, 256 chips), 2 pods multi-pod.

A function, not a module constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
