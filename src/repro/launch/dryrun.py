"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init).  Run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch <id> --shape <cell>
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each cell produces experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, and parsed collective bytes — the §Roofline
inputs."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_arch_names, get_config
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        data_axes, fit_spec, params_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, collective_bytes, model_flops
from repro.launch.specs import SHAPES, input_specs, optimizer_kind
from repro.models import decode_step, loss_fn, prefill
from repro.train.optim import OptConfig
from repro.train.step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def opt_shardings(cfg, mesh, params_sds, opt_sds, kind: str):
    p_sh = params_shardings(cfg, mesh, params_sds)
    rep = NamedSharding(mesh, P())
    if kind == "adamw":
        return {"mu": p_sh, "nu": p_sh, "step": rep}

    # adafactor: vr drops the last dim of the param spec, vc the 2nd-to-last
    def slot_sh(p_leaf_sh, slot):
        spec = tuple(p_leaf_sh.spec)
        out = {}
        for k, v in slot.items():
            nd = len(v.shape)
            if k == "vr":
                s = spec[:-1]
            elif k == "vc":
                s = spec[:-2] + spec[-1:]
            else:
                s = spec
            s = tuple(s)[:nd]
            s = s + (None,) * (nd - len(s))
            out[k] = NamedSharding(mesh, fit_spec(mesh, P(*s), v.shape))
        return out

    flat_p = jax.tree_util.tree_leaves(
        p_sh, is_leaf=lambda x: isinstance(x, NamedSharding))
    pdef = jax.tree_util.tree_structure(params_sds)
    flat_slots = pdef.flatten_up_to(opt_sds["slots"])
    slots = pdef.unflatten([slot_sh(s, sl)
                            for s, sl in zip(flat_p, flat_slots)])
    return {"slots": slots, "step": rep}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               seq_shard: bool = False, verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch skips long_500k (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    bundle = input_specs(cfg, shape_name)
    p_sh = params_shardings(cfg, mesh, bundle["params"])
    b_sh = batch_shardings(cfg, mesh, bundle["batch"], seq_shard=seq_shard)

    t0 = time.time()
    with mesh:
        if cell.kind == "train":
            oc = OptConfig(kind=optimizer_kind(cfg))
            step = make_train_step(cfg, oc)
            o_sh = opt_shardings(cfg, mesh, bundle["params"], bundle["opt"],
                                 oc.kind)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(bundle["params"], bundle["opt"],
                                   bundle["batch"])
        elif cell.kind == "prefill":
            fn = lambda p, b: prefill(cfg, p, b, cell.seq_len)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(bundle["params"], bundle["batch"])
        else:  # decode
            c_sh = cache_shardings(cfg, mesh, bundle["cache"])
            dp = data_axes(mesh)
            t_shape = bundle["batch"]["tokens"].shape
            t_sh = NamedSharding(mesh, fit_spec(mesh, P(dp, None), t_shape))
            pos_sh = NamedSharding(mesh, P())
            fn = lambda p, c, t, pos: decode_step(cfg, p, c, t, pos)
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(bundle["params"], bundle["cache"],
                                   bundle["batch"]["tokens"], bundle["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze
    hla = analyze(hlo)  # loop-aware (cost_analysis counts loop bodies once)

    mem_info = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            mem_info[attr] = int(getattr(mem, attr))

    # the SPMD module is per-device: scale to global for the roofline terms
    rl = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(hla["flops"]) * chips,
        hlo_bytes=float(hla["hbm_bytes"]) * chips,
        coll_bytes={k: int(v * chips) for k, v in hla["coll_bytes"].items()},
        model_flops=model_flops(cfg, cell),
        bytes_per_device=mem_info.get("temp_size_in_bytes"),
    )
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": rl.hlo_flops, "bytes": rl.hlo_bytes,
        "cost_analysis_flops": float(cost.get("flops", 0.0)),
        "collective_bytes": rl.coll_bytes, "memory": mem_info,
        "n_whiles": hla["n_whiles"],
        "model_flops": rl.model_flops,
        "t_compute_ms": rl.t_compute * 1e3,
        "t_memory_ms": rl.t_memory * 1e3,
        "t_collective_ms": rl.t_collective * 1e3,
        "dominant": rl.dominant,
        "useful_fraction": rl.useful_fraction,
        "roofline_fraction": rl.roofline_fraction,
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] OK "
              f"comp={rl.t_compute*1e3:.2f}ms mem={rl.t_memory*1e3:.2f}ms "
              f"coll={rl.t_collective*1e3:.2f}ms dom={rl.dominant} "
              f"useful={rl.useful_fraction*100:.0f}% "
              f"roofline={rl.roofline_fraction*100:.1f}% "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        if mem_info:
            print(f"    memory_analysis: {mem_info}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="SP: shard the sequence dim over the model axis")
    ap.add_argument("--sp-residual", action="store_true",
                    help="sequence-parallel residual stream (perf iter 3)")
    ap.add_argument("--paper-baseline", action="store_true",
                    help="pre-hillclimb behaviour: global MoE dispatch, "
                         "full remat, (B,T,T) attention bias at T<=4096")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    if args.sp_residual:
        from repro.models.model import set_seq_shard_residual
        set_seq_shard_residual(True)
    if args.paper_baseline:
        from repro.models import moe as moe_mod
        from repro.models import attention as attn_mod
        from repro.models.model import set_remat_policy
        moe_mod.set_dispatch("global")
        attn_mod.set_full_attention_threshold(4096)
        set_remat_policy("full")
    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = all_arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{'pod2x16x16' if args.multi_pod else 'pod16x16'}"
        path = os.path.join(args.out, tag + ".json")
        try:
            res = lower_cell(arch, shape, multi_pod=args.multi_pod,
                             seq_shard=args.seq_shard)
        except Exception as e:  # a cell failure is a bug in the system
            failures += 1
            res = {"arch": arch, "shape": shape, "status": "FAILED",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[{arch} × {shape}] FAILED: {type(e).__name__}: {e}")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    print(f"\n{len(cells) - failures}/{len(cells)} cells OK")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
