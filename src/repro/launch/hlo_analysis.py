"""Loop-aware HLO accounting (FLOPs / collective bytes / HBM traffic).

``compiled.cost_analysis()`` on the CPU backend counts every while-loop
body **once**; with scan-over-layers and chunked-attention scans that
undercounts FLOPs by orders of magnitude (verified in EXPERIMENTS.md
§Roofline notes).  This module re-derives the terms from the optimized HLO
text with loop multiplication:

1. split the module into named computations;
2. per computation: sum dot FLOPs (2·|out|·K), collective result bytes,
   and parameter/output bytes for fusions;
3. build the call graph (``calls=``, ``to_apply=``, while ``body=``/
   ``condition=``); while bodies multiply by a trip count parsed from the
   loop condition's comparison constant (best-effort, defaults to 1);
4. roll up from the entry computation.

This is structural analysis of the compiled artifact — exactly what the
dry-run has instead of a wall clock.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "u1": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1, "f4e2m1fn": 1, "e8m0fnu": 1,
}

# Longest alternatives first: "f8e4m3fn" must win over the bare "[suf]\d+"
# prefix "f8" (which would then fail on the following "e…" and drop the
# shape entirely).
_DTYPE_ALT = (r"pred|bf16|f8e4m3b11fnuz|f8e4m3fnuz|f8e4m3fn|f8e5m2fnuz|"
              r"f8e5m2|f8e3m4|f8e4m3|f4e2m1fn|e8m0fnu|c64|c128|u1|[suf]\d+")
_SHAPE_RE = re.compile(r"(%s)\[([\d,]*)\]" % _DTYPE_ALT)


def _first_shape(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _result_section(rhs: str) -> str:
    """The result-type span of an assignment's rhs.

    Tuple-result ops — ``(f32[8,16]{1,0}, s32[]) fusion(...)`` — break the
    naive ``rhs.split("(")[0]`` (empty string → 0 bytes, silently): the
    result type itself starts with a paren.  Balanced-paren scan returns
    the whole tuple type; scalar results keep the text before the op's
    open paren."""
    s = rhs.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[:i + 1]
        return s
    return s.split("(", 1)[0]


def _shape_elems(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    hbm_bytes: float = 0.0
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
# op-call position only: operands are %-prefixed var names (which reuse op
# names, e.g. %all-reduce.178) and must not match
_COLL_KIND_RE = re.compile(
    r"(?<!%)\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_DOT_RE = re.compile(r"=\s*(?:\(?)([\w\[\],{}\s]+?)\s*dot\(")
_TRIP_RE = re.compile(r"compare\([^)]*\)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$", s)
        if m and ("{" in s) and ("=" not in s.split("{")[0]):
            cur = m.group(1)
            comps[cur] = []
            continue
        m2 = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", s)
        if cur is None and m2:
            cur = m2.group(2)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _build_symtab(lines: List[str]) -> Dict[str, Tuple[str, List[int]]]:
    """var name → (dtype, dims), from assignment lines + header params."""
    tab: Dict[str, Tuple[str, List[int]]] = {}
    for s in lines:
        m = _DEF_RE.match(s)
        if not m:
            # computation headers carry 'name: f32[a,b]' params
            for pm in re.finditer(r"%?([\w.\-]+):\s*(" + _DTYPE_ALT +
                                  r")\[([\d,]*)\]", s):
                tab[pm.group(1)] = (pm.group(2),
                                    [int(d) for d in pm.group(3).split(",")
                                     if d])
            continue
        res = _result_section(s.split("=", 1)[1])
        if res.startswith("("):
            continue  # tuple result: the var is not a single shaped array
        sh = _first_shape(res)
        if sh:
            tab[m.group(1)] = sh
    return tab


def _line_flops(s: str, symtab: Dict[str, List[int]]) -> float:
    """FLOPs of one HLO line (dots dominate; elementwise ignored)."""
    if " dot(" not in s:
        return 0.0
    res = _first_shape(_result_section(s.split("=", 1)[1])) \
        if "=" in s else None
    if res is None:
        return 0.0
    _, out_dims = res
    out_n = _shape_elems(out_dims)
    # contraction size: product of lhs operand's contracting dims
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
    k = 1
    inner = s.split(" dot(", 1)[1]
    ops = _OPERAND_RE.findall(inner)
    if mc and ops and ops[0] in symtab:
        ldims = symtab[ops[0]][1]
        for ci in mc.group(1).split(","):
            if ci and int(ci) < len(ldims):
                k *= ldims[int(ci)]
    return 2.0 * out_n * k


def _line_coll(s: str) -> Optional[Tuple[str, float]]:
    if "=" not in s:
        return None
    rhs = s.split("=", 1)[1]
    m = re.search(_COLL_KIND_RE, rhs)
    if not m or m.group(2) == "-done":
        return None
    # result type(s) precede the op name on the rhs
    b = _all_shapes_bytes(rhs.split(m.group(1))[0])
    return m.group(1), float(b)


def analyze(hlo: str) -> Dict[str, object]:
    comps = split_computations(hlo)
    stats: Dict[str, CompStats] = {}
    whiles: List[Tuple[str, str, str]] = []  # (comp, cond, body)

    # (?<!-) keeps 'dynamic-update-slice(' from matching as 'slice('
    _SLICE_OPS = re.compile(r"\b(dynamic-slice|gather|(?<![\w-])slice)\(")
    _PASS_OPS = re.compile(r"\b(bitcast|reshape|copy|convert|transpose|"
                           r"broadcast)\(")

    def _var_bytes(symtab, var) -> float:
        if var not in symtab:
            return 0.0
        dt, dims = symtab[var]
        return _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)

    def _param_read_bytes(callee: str) -> Optional[List[Optional[float]]]:
        """Per-parameter effective read bytes inside a fused computation.

        Follows pass-through chains (bitcast/reshape/…) to eventual
        slice/gather consumers: a parameter only read through slices costs
        the slices' result bytes; a dynamic-update-slice target costs
        2× the update; any heavier use costs the full parameter (None).
        """
        lines = comps.get(callee)
        if lines is None:
            return None
        symtab = _build_symtab(lines)
        params: Dict[int, str] = {}
        defline: Dict[str, str] = {}
        uses: Dict[str, List[Tuple[str, str]]] = {}
        for s in lines:
            m = _DEF_RE.match(s)
            if not m:
                continue
            dvar = m.group(1)
            defline[dvar] = s
            pm = re.search(r"parameter\((\d+)\)", s)
            if pm:
                params[int(pm.group(1))] = dvar
            inner = s.split("(", 1)[1] if "(" in s else ""
            for op in _OPERAND_RE.findall(inner):
                uses.setdefault(op, []).append((s, dvar))
        if not params:
            return None

        memo: Dict[str, Optional[float]] = {}

        def eff(var: str, depth: int = 0) -> Optional[float]:
            """Effective read bytes of ``var`` (None = read fully)."""
            if var in memo:
                return memo[var]
            if depth > 16:
                return None
            total = 0.0
            for s, dvar in uses.get(var, ()):
                inner_ops = _OPERAND_RE.findall(s.split("(", 1)[1]) \
                    if "(" in s else []
                if _SLICE_OPS.search(s) and inner_ops and \
                        inner_ops[0] == var:
                    sh = _first_shape(s.split("=", 1)[1])
                    total += (_shape_elems(sh[1]) *
                              _DTYPE_BYTES.get(sh[0], 4)) if sh else 0.0
                elif " dynamic-update-slice(" in s and inner_ops and \
                        inner_ops[0] == var:
                    upd = inner_ops[1] if len(inner_ops) > 1 else None
                    total += 2.0 * _var_bytes(symtab, upd) if upd else 0.0
                elif _PASS_OPS.search(s):
                    sub = eff(dvar, depth + 1)
                    if sub is None:
                        memo[var] = None
                        return None
                    total += min(sub, _var_bytes(symtab, dvar))
                else:
                    memo[var] = None
                    return None
            memo[var] = total
            return total

        out: List[Optional[float]] = [None] * (max(params) + 1)
        for idx, var in params.items():
            out[idx] = eff(var)
        return out

    _OPCODE_RE = re.compile(r"([\w\-]+)\(")
    _BOOKKEEPING = {"get-tuple-element", "tuple", "parameter", "constant",
                    "bitcast", "conditional", "call", "copy",
                    "copy-start", "copy-done", "after-all", "custom-call",
                    "partition-id", "replica-id", "optimization-barrier"}
    # `copy` is loop double-buffering the runtime aliases/elides — charging
    # it would claim TBs of phantom traffic per scan iteration.
    _SLICE_LIKE = {"dynamic-slice", "gather", "slice", "broadcast", "iota",
                   "reshape", "transpose", "convert", "reverse", "pad",
                   "concatenate"}

    trip: Dict[str, float] = {}
    for name, lines in comps.items():
        st = CompStats()
        symtab = _build_symtab(lines)
        for s in lines:
            st.flops += _line_flops(s, symtab)
            c = _line_coll(s)
            if c:
                st.coll_bytes[c[0]] = st.coll_bytes.get(c[0], 0.0) + c[1]
            if "=" not in s:
                continue
            rhs = s.split("=", 1)[1]
            mo = _OPCODE_RE.search(rhs)
            opcode = mo.group(1) if mo else ""

            if opcode == "while":
                mw = re.search(r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)",
                               s)
                if mw:
                    whiles.append((name, mw.group(1), mw.group(2)))
                    st.calls.append(("__while__" + mw.group(2), 1.0))
                    st.calls.append((mw.group(1), 1.0))  # condition, ×1
                    mt = re.search(r'known_trip_count..:..n.:.(\d+)', s)
                    if mt:
                        trip[mw.group(2)] = max(trip.get(mw.group(2), 1.0),
                                                float(mt.group(1)))
                continue

            # call-graph edges: fusion calls=, reduce/sort to_apply=,
            # conditional branch computations — strip metadata first so
            # op_name strings never alias computation names
            body_txt = rhs.split("metadata=")[0]
            for cm in _OPERAND_RE.finditer(body_txt):
                callee = cm.group(1)
                if callee in comps and callee != name:
                    st.calls.append((callee, 1.0))

            # --- HBM traffic ≈ per top-level kernel ------------------------
            if opcode in _BOOKKEEPING or not opcode:
                continue
            res_b = _all_shapes_bytes(_result_section(rhs))
            if opcode in _SLICE_LIKE:
                st.hbm_bytes += 2.0 * res_b
                continue
            if opcode == "dynamic-update-slice":
                ops = _OPERAND_RE.findall(rhs.split("(", 1)[1])
                upd = symtab.get(ops[1]) if len(ops) > 1 else None
                if upd:
                    st.hbm_bytes += 2.0 * _shape_elems(upd[1]) * \
                        _DTYPE_BYTES.get(upd[0], 4)
                    continue
            per_param = None
            b = res_b
            if opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", s)
                if fm:
                    callee = fm.group(1)
                    per_param = _param_read_bytes(callee)
                    # in-place dus fusion: result aliases the input buffer —
                    # the true write is the update slice (2×update charge
                    # lives in per_param[0])
                    fbody = "\n".join(comps.get(callee, ()))
                    if " dynamic-update-slice(" in fbody and \
                            per_param and per_param[0] is not None:
                        b = 0.0
            inner = body_txt.split("(", 1)[1] if "(" in body_txt else ""
            for oi, op in enumerate(_OPERAND_RE.findall(inner)[:16]):
                if op not in symtab:
                    continue
                dt, dims = symtab[op]
                full = _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
                if per_param is not None and oi < len(per_param) and \
                        per_param[oi] is not None:
                    b += min(per_param[oi], full)
                else:
                    b += full
            st.hbm_bytes += b
        stats[name] = st

    # fallback trip counts: largest comparison constant in the condition
    for _, cond, body in whiles:
        if body in trip:
            continue
        consts = [int(x) for m in comps.get(cond, ())
                  for x in _CONST_RE.findall(m)]
        trip[body] = float(max(consts)) if consts else 1.0

    memo: Dict[str, Tuple[float, Dict[str, float], float]] = {}

    def roll(name: str, depth=0):
        if name in memo:
            return memo[name]
        if depth > 64 or name not in stats:
            return 0.0, {}, 0.0
        st = stats[name]
        f = st.flops
        cb = dict(st.coll_bytes)
        hb = st.hbm_bytes
        for callee, mult in st.calls:
            if callee.startswith("__while__"):
                body = callee[len("__while__"):]
                m = trip.get(body, 1.0)
                bf, bcb, bhb = roll(body, depth + 1)
                f += m * bf
                hb += m * bhb
                for k, v in bcb.items():
                    cb[k] = cb.get(k, 0.0) + m * v
            else:
                bf, bcb, bhb = roll(callee, depth + 1)
                f += bf
                # fusion-internal traffic is VMEM-local: call-site counted
                if not (callee.startswith("fused") or
                        callee.startswith("wrapped")):
                    hb += bhb
                for k, v in bcb.items():
                    cb[k] = cb.get(k, 0.0) + v
        memo[name] = (f, cb, hb)
        return memo[name]

    # entry: the ENTRY-marked computation (fall back to uncalled roots)
    em = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if em and em.group(1) in stats:
        entries = [em.group(1)]
    else:  # pragma: no cover - older text formats
        called = set()
        for st in stats.values():
            for c, _ in st.calls:
                called.add(c[len("__while__"):]
                           if c.startswith("__while__") else c)
        entries = [n for n in stats if n not in called]
    f_tot, cb_tot, hb_tot = 0.0, {}, 0.0
    for e in entries:
        f, cb, hb = roll(e)
        f_tot += f
        hb_tot += hb
        for k, v in cb.items():
            cb_tot[k] = cb_tot.get(k, 0.0) + v
    return {"flops": f_tot, "coll_bytes": cb_tot, "hbm_bytes": hb_tot,
            "n_computations": len(comps), "n_whiles": len(whiles),
            "trips": trip}
