"""Input/state ShapeDtypeStruct specs per (arch × shape) cell.

Nothing here allocates: parameters/optimizer/caches come from
``jax.eval_shape`` over the real init functions, inputs are synthesized
ShapeDtypeStructs — the same pattern as a real AOT launcher."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import init_cache, init_params
from repro.models.layers import dtype_of

N_PATCHES = 256     # vlm stub patches prepended to the text sequence


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, cell: ShapeCell) -> Dict[str, Any]:
    B, T = cell.global_batch, cell.seq_len
    if cell.kind == "decode":
        specs = {"tokens": _sds((B, 1), jnp.int32)}
        return specs
    specs = {"tokens": _sds((B, T), jnp.int32)}
    if cell.kind == "train":
        specs["labels"] = _sds((B, T), jnp.int32)
    if cfg.family == "vlm":
        specs["patch_embeds"] = _sds((B, N_PATCHES, cfg.d_model), jnp.float32)
        specs["positions"] = _sds((B, T + N_PATCHES, 3), jnp.int32)
    if cfg.enc_dec:
        specs["frames"] = _sds((B, cfg.encoder_len, cfg.d_model), jnp.float32)
    return specs


def params_specs(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def cache_specs(cfg, cell: ShapeCell):
    B = cell.global_batch
    return jax.eval_shape(lambda: init_cache(cfg, B, cell.seq_len))


def opt_specs(cfg, params_sds, kind: str):
    from repro.train.optim import OptConfig, init_opt
    oc = OptConfig(kind=kind)
    return jax.eval_shape(functools.partial(init_opt, oc), params_sds)


def optimizer_kind(cfg) -> str:
    """Adafactor where AdamW state cannot fit (deepseek-scale / fsdp)."""
    return "adafactor" if cfg.fsdp else "adamw"


def input_specs(cfg, shape_name: str):
    """The full spec bundle the dry-run lowers against."""
    cell = SHAPES[shape_name]
    p = params_specs(cfg)
    out = {"cell": cell, "params": p, "batch": batch_specs(cfg, cell)}
    if cell.kind == "train":
        out["opt"] = opt_specs(cfg, p, optimizer_kind(cfg))
    if cell.kind == "decode":
        out["cache"] = cache_specs(cfg, cell)
        out["pos"] = _sds((), jnp.int32)
    return out
