"""End-to-end training driver with scrutinized checkpointing + restart.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 200 --batch 8 --seq 256 [--preset smoke] [--resume]

The loop wires every substrate together: data pipeline (resumable, its
state checkpointed), train step, async multi-level CheckpointManager with
the AD-scrutinized reduction, and crash-equivalent restart (the integration
test kills and resumes mid-run and checks loss-curve continuation).

Multi-host runs (``jax.process_count() > 1``, the ``REPRO_PROCESS_*``
simulation env, or ``--coordinated``) go through the
``CoordinatedCheckpointManager``: every host writes only the shards it
owns, the step commits via the collective two-phase protocol, and
``--resume`` restores elastically onto whatever process count is alive.
On a single process the coordinator delegates to the pipelined async
manager, so the wiring is unconditional.

``--preset smoke`` shrinks the model (CPU CI); on real hardware use the
full config with --mesh data,model sizes.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CoordinatedCheckpointManager, Level
from repro.configs import get_config
from repro.launch.compile_cache import enable_persistent_cache
from repro.distributed.collective import current_context, get_collective
from repro.core import ScrutinyConfig, participation
from repro.data import pipeline as data_pipeline
from repro.models import init_params, count_params
from repro.train.optim import OptConfig, init_opt
from repro.train.step import make_train_step


def build_state(cfg, oc, batch, seq, seed=0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt(oc, params)
    data_state = data_pipeline.init_state(cfg, batch, seq, seed=seed)
    return {"params": params, "opt": opt_state, "data": data_state,
            "step": jnp.zeros((), jnp.int32)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--scrutinize", action="store_true",
                    help="reduce checkpoints with participation analysis")
    ap.add_argument("--verify-static", action="store_true",
                    help="scrutinize with the AD probe engine, prune the "
                         "sweep with the static analyzer, and gate every "
                         "report on the AD⊆static soundness check "
                         "(repro.analysis)")
    ap.add_argument("--coordinated", action="store_true",
                    help="force the multi-host coordinated save path even "
                         "on one process (it is automatic when "
                         "jax.process_count() > 1 or REPRO_PROCESS_COUNT "
                         "is set)")
    ap.add_argument("--coord-dir", default=None,
                    help="shared rendezvous dir for the filesystem-barrier "
                         "fallback (default: <ckpt-dir>/coord)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--task", default="lm", choices=["lm", "copy"],
                    help="lm: next-token; copy: identity (fast smoke signal)")
    ap.add_argument("--lr", type=float, default=None)
    args = ap.parse_args(argv)

    # persistent XLA cache: relaunches (and --resume restarts) skip the
    # multi-second train-step + scrutiny-sweep compiles
    cache = enable_persistent_cache()
    if cache:
        print(f"compilation cache: {cache}")

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    smoke = args.preset == "smoke"
    lr = args.lr if args.lr is not None else (3e-3 if smoke else 3e-4)
    oc = OptConfig(kind="adamw", lr=lr, warmup=5 if smoke else 100,
                   clip_norm=10.0 if smoke else 1.0, decay_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, oc))

    state = build_state(cfg, oc, args.batch, args.seq)
    print(f"arch={cfg.name} params={count_params(state['params'])/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    scrutiny_fn = None
    soundness_check = None
    if args.scrutinize or args.verify_static:
        # "the rest of the program" for a train checkpoint: the next train
        # step from the data pipeline's next batch.  One stable fn object,
        # so the shared jaxpr trace cache hits across scrutiny/static/lint.
        def resume(s):
            batch, _ = data_pipeline.next_batch(cfg, s["data"])
            _, _, metrics = step_fn(s["params"], s["opt"], batch)
            return {"loss": metrics["loss"]}

        if args.verify_static:
            from repro.analysis import soundness_checker
            from repro.core import scrutinize

            scfg = ScrutinyConfig(static_prune=True)

            def scrutiny_fn(host_state):
                return scrutinize(resume, host_state, config=scfg)

            soundness_check = soundness_checker(resume)
            print("static verification: soundness gate + probe-sweep "
                  "pruning enabled")
        else:
            def scrutiny_fn(host_state):
                return participation(resume, host_state,
                                     config=ScrutinyConfig())

    # Coordinated when the job spans processes (real multi-controller or
    # the REPRO_PROCESS_* simulation); single-process jobs delegate to the
    # pipelined async manager inside, so the wiring is unconditional.
    ctx = current_context()
    coordinated = args.coordinated or ctx.count > 1
    collective = get_collective(
        coord_dir=args.coord_dir or os.path.join(args.ckpt_dir, "coord"))
    parity = not coordinated             # per-host parity: future level
    mgr = CoordinatedCheckpointManager(
        [Level(os.path.join(args.ckpt_dir, "ram"), interval=args.ckpt_every,
               keep_n=2),
         Level(os.path.join(args.ckpt_dir, "disk"),
               interval=args.ckpt_every * 4, keep_n=2, shards=2,
               parity=parity)],
        collective=collective, scrutiny_fn=scrutiny_fn,
        soundness_check=soundness_check,
        force_coordinated=args.coordinated)
    if coordinated:
        print(f"coordinated checkpointing: process {ctx.index} of "
              f"{ctx.count}")

    start = 0
    if args.resume:
        got = mgr.restore(state)
        if got is not None:
            start, state = got
            print(f"resumed from step {start}")

    losses = []
    t0 = time.time()
    for step in range(start + 1, args.steps + 1):
        batch, state["data"] = data_pipeline.next_batch(cfg, state["data"])
        if args.task == "copy":
            batch = {"tokens": batch["tokens"], "labels": batch["tokens"]}
        state["params"], state["opt"], metrics = step_fn(
            state["params"], state["opt"], batch)
        state["step"] = jnp.asarray(step, jnp.int32)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms/step")
            t0 = time.time()
        if step % args.ckpt_every == 0:
            mgr.save(step, state)
    mgr.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
