"""JAX persistent compilation cache wiring for bench + launch paths.

The device scrutiny engine's multi-probe vjp sweep costs ~2 s of XLA
compile the first time a (state structure, probe count) pair is seen —
per *process*, so every training relaunch and every benchmark run pays
it again even though the jaxpr is identical.  XLA's persistent
compilation cache keys serialized executables on the HLO fingerprint and
serves later compiles from disk, turning the relaunch cost into a
millisecond-scale cache read.

``enable_persistent_cache()`` points JAX at a stable on-disk cache
directory (``$REPRO_COMPILE_CACHE``, or ``~/.cache/repro/jax`` when
unset; ``REPRO_COMPILE_CACHE=0`` disables) and drops the min-compile-time
/ min-entry-size thresholds so the scrutiny sweep and the packed-save
kernels are always cached.  Every knob is set best-effort: older JAX
versions without a given config simply skip it, and a read-only cache
directory disables the cache rather than failing the launch.
"""

from __future__ import annotations

import os
from typing import Optional

_DISABLE = ("0", "off", "none", "disable")


def default_cache_dir() -> Optional[str]:
    """Resolve the cache dir from ``$REPRO_COMPILE_CACHE`` (None = off)."""
    env = os.environ.get("REPRO_COMPILE_CACHE")
    if env is not None:
        return None if env.strip().lower() in _DISABLE else env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "jax")


def enable_persistent_cache(cache_dir: Optional[str] = None) -> Optional[str]:
    """Turn on JAX's persistent compilation cache.

    Returns the active cache directory, or None when disabled (explicitly
    via env, or because the directory cannot be created).  Idempotent and
    safe to call before any jit compilation in a process.
    """
    import jax

    d = cache_dir if cache_dir is not None else default_cache_dir()
    if d is None:
        return None
    try:
        os.makedirs(d, exist_ok=True)
    except OSError:
        return None
    opts = [
        ("jax_compilation_cache_dir", d),
        # cache everything: the scrutiny sweep's helper jits are small but
        # sit on the relaunch path too
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
        ("jax_persistent_cache_min_entry_size_bytes", -1),
        # cover the XLA-side autotune/kernel caches where supported
        ("jax_persistent_cache_enable_xla_caches", "all"),
    ]
    for name, value in opts:
        try:
            jax.config.update(name, value)
        except (AttributeError, ValueError, TypeError):
            pass                    # older JAX: knob absent — best effort
    try:
        # the cache object is initialized lazily *once*; re-pointing the
        # dir mid-process (bench cold/warm runs) needs an explicit reset
        from jax.experimental.compilation_cache import compilation_cache
        compilation_cache.reset_cache()
    except (ImportError, AttributeError):
        pass
    return d
